//! Out-of-core invariant suite for the spilled `Block` backend and the
//! sparse row-slab layout:
//!
//! * the spilled backend is **bit-identical** to dense for Algorithms
//!   2, 7 and 8, across worker counts 1/2/4 and cache budgets
//!   {unbounded, two blocks, one block};
//! * `peak_resident_bytes ≤ budget` on every run, and spilling adds
//!   **zero** `a_passes` over the all-resident plan;
//! * results are independent of eviction order / access interleaving;
//! * fault injection — truncating, corrupting, or deleting a spill
//!   file mid-run — surfaces a clean typed [`SpillError`] through the
//!   `try_*` APIs (no panic, no silent wrong numbers), and the temp
//!   directory is removed on drop even on the error path;
//! * the sparse tall pipeline (`DistRowCsrMatrix` through `DistOp`)
//!   recovers an exactly prescribed spectrum end-to-end.

use dsvd::algs::{algorithm2, algorithm7, algorithm8, DistSvd, LowRankOpts, TallSkinnyOpts};
use dsvd::dist::{BlockStorage, Context, DistBlockMatrix, SpillError, SpillStore};
use dsvd::gen::{SparseRandTestMatrix, SparseSpectrumTestMatrix};
use dsvd::linalg::Matrix;
use dsvd::runtime::compute::NativeCompute;
use std::path::PathBuf;
use std::sync::Arc;

const RPB: usize = 32;
const CPB: usize = 32;

/// Bytes of one full 32x32 dense block payload.
fn block_bytes() -> usize {
    8 * RPB * CPB
}

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 32;
    o
}

type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);

fn snapshot(out: &DistSvd) -> Snapshot {
    (
        out.s.clone(),
        out.v.data().to_vec(),
        out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
    )
}

fn dense_fixture(ctx: &Context) -> DistBlockMatrix {
    SparseRandTestMatrix::new(96, 64, 0.25, 0x00C).generate(ctx, RPB, CPB, BlockStorage::Dense)
}

fn spill_files(store: &SpillStore) -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(store.dir())
        .expect("spill dir readable")
        .map(|e| e.expect("dir entry").path())
        .collect();
    files.sort();
    files
}

#[test]
fn spilled_bit_identical_to_dense_across_budgets_and_workers() {
    let budgets = [usize::MAX, 2 * block_bytes(), block_bytes()];
    for workers in [1usize, 2, 4] {
        let ctx = Context::new(8).with_workers(workers);
        let dense = dense_fixture(&ctx);
        let want7 = algorithm7(&ctx, &NativeCompute, &dense, &opts(8, 2));
        let want8 = algorithm8(&ctx, &NativeCompute, &dense, &opts(8, 2));
        let rows_ref = dense.try_to_rows(&ctx).expect("dense to_rows");
        let want2 = algorithm2(&ctx, &NativeCompute, &rows_ref, &TallSkinnyOpts::default());

        for budget in budgets {
            let store = SpillStore::with_budget(budget).expect("spill store");
            let spilled = dense.spill(&ctx, &store).expect("spill");
            let label = format!("workers={workers} budget={budget}");

            ctx.reset_metrics();
            let got7 = algorithm7(&ctx, &NativeCompute, &spilled, &opts(8, 2));
            let m7 = ctx.take_metrics();
            assert_eq!(snapshot(&got7), snapshot(&want7), "{label}: alg7 changed bits");
            assert!(
                m7.peak_resident_bytes <= budget,
                "{label}: alg7 resident {} over budget",
                m7.peak_resident_bytes
            );

            ctx.reset_metrics();
            let got8 = algorithm8(&ctx, &NativeCompute, &spilled, &opts(8, 2));
            let m8 = ctx.take_metrics();
            assert_eq!(snapshot(&got8), snapshot(&want8), "{label}: alg8 changed bits");
            assert!(m8.peak_resident_bytes <= budget, "{label}: alg8 over budget");

            // Algorithm 2 consumes the grid through the row-slab bridge
            ctx.reset_metrics();
            let rows = spilled.try_to_rows(&ctx).expect("spilled to_rows");
            let got2 = algorithm2(&ctx, &NativeCompute, &rows, &TallSkinnyOpts::default());
            let m2 = ctx.take_metrics();
            assert_eq!(snapshot(&got2), snapshot(&want2), "{label}: alg2 changed bits");
            assert!(m2.peak_resident_bytes <= budget, "{label}: alg2 over budget");
        }
    }
}

#[test]
fn spilling_adds_no_passes() {
    // same algorithm, same ledger: the out-of-core tier must not cost
    // extra traversals of A — a one-block budget pays re-READS of the
    // spill files (visible in spill_bytes_read), never extra passes
    let ctx = Context::new(8);
    let dense = dense_fixture(&ctx);
    let (nbr, nbc) = dense.num_blocks();

    ctx.reset_metrics();
    let _ = algorithm7(&ctx, &NativeCompute, &dense, &opts(8, 2));
    let m_dense = ctx.take_metrics();

    let mut reads = Vec::new();
    for budget in [usize::MAX, block_bytes()] {
        let store = SpillStore::with_budget(budget).expect("spill store");
        let spilled = dense.spill(&ctx, &store).expect("spill");
        ctx.reset_metrics();
        let _ = algorithm7(&ctx, &NativeCompute, &spilled, &opts(8, 2));
        let m = ctx.take_metrics();
        assert_eq!(m.a_passes, m_dense.a_passes, "budget={budget}: extra passes");
        assert_eq!(
            m.blocks_materialized, m_dense.blocks_materialized,
            "budget={budget}: extra block accesses"
        );
        assert!(m.spill_bytes_read > 0, "budget={budget}: no pages read?");
        reads.push(m.spill_bytes_read);
    }
    // unbounded cache: every block read once, then resident; one-block
    // cache: most passes re-read most blocks
    assert!(
        reads[1] > reads[0],
        "one-block budget must re-read more than all-resident ({} vs {})",
        reads[1],
        reads[0]
    );
    assert_eq!(reads[0], nbr * nbc * block_bytes(), "all-resident reads each block once");
}

#[test]
fn results_independent_of_eviction_order() {
    let ctx = Context::new(4);
    let be = NativeCompute;
    let dense = dense_fixture(&ctx);
    let w = Matrix::from_fn(64, 5, |i, j| ((i * 7 + j * 3) as f64).sin());
    let want = dense.matmul_small(&ctx, &be, &w).collect(&ctx);
    let ones = vec![1.0f64; 96];

    for budget in [usize::MAX, 2 * block_bytes(), block_bytes()] {
        let store = SpillStore::with_budget(budget).expect("spill store");
        let spilled = dense.spill(&ctx, &store).expect("spill");
        // interleaving A: straight product on a cold cache
        let ya = spilled.matmul_small(&ctx, &be, &w).collect(&ctx);
        // interleaving B: touch the blocks in other orders first (a
        // transpose-side pass and a full gather churn the LRU), then
        // the same product on a warm, differently-populated cache
        let _ = spilled.rmatvec(&ctx, &ones);
        let _ = spilled.try_collect(&ctx).expect("collect");
        let yb = spilled.matmul_small(&ctx, &be, &w).collect(&ctx);
        assert_eq!(ya.data(), yb.data(), "budget={budget}: access history changed bits");
        assert_eq!(ya.data(), want.data(), "budget={budget}: spilled product differs");
    }
}

#[test]
fn truncated_spill_file_is_a_typed_error() {
    let ctx = Context::new(2);
    let dense = dense_fixture(&ctx);
    let store = SpillStore::with_budget(block_bytes()).expect("spill store");
    let spilled = dense.spill(&ctx, &store).expect("spill");
    assert!(spilled.try_collect(&ctx).is_ok(), "healthy grid must collect");

    for path in spill_files(&store) {
        let full = std::fs::read(&path).expect("read payload");
        std::fs::write(&path, &full[..40]).expect("truncate payload");
    }
    let err = spilled.try_collect(&ctx).expect_err("truncated payloads must fail");
    assert!(matches!(err, SpillError::Corrupt { .. }), "want Corrupt, got: {err}");

    // the fallible product surface reports the same typed error
    let w = Matrix::from_fn(64, 3, |i, j| ((i + j) as f64).cos());
    assert!(spilled.try_matmul_small(&ctx, &NativeCompute, &w).is_err());
    assert!(spilled.try_matvec(&ctx, &[1.0; 64]).is_err());
}

#[test]
fn corrupted_spill_file_is_a_typed_error_not_wrong_numbers() {
    let ctx = Context::new(2);
    let dense = dense_fixture(&ctx);
    let store = SpillStore::with_budget(block_bytes()).expect("spill store");
    let spilled = dense.spill(&ctx, &store).expect("spill");
    assert!(spilled.try_collect(&ctx).is_ok());

    // flip one payload byte in every file: lengths stay valid, so only
    // the checksum can catch it — silence here would be wrong numbers
    for path in spill_files(&store) {
        let mut bytes = std::fs::read(&path).expect("read payload");
        let mid = 32 + (bytes.len() - 32) / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).expect("corrupt payload");
    }
    let err = spilled.try_collect(&ctx).expect_err("corrupt payloads must fail");
    match &err {
        SpillError::Corrupt { detail, .. } => {
            assert!(detail.contains("checksum"), "want a checksum failure, got: {detail}")
        }
        other => panic!("want Corrupt, got: {other}"),
    }
}

#[test]
fn deleted_spill_file_is_a_typed_error() {
    let ctx = Context::new(2);
    let dense = dense_fixture(&ctx);
    let store = SpillStore::with_budget(block_bytes()).expect("spill store");
    let spilled = dense.spill(&ctx, &store).expect("spill");
    assert!(spilled.try_collect(&ctx).is_ok());

    for path in spill_files(&store) {
        std::fs::remove_file(&path).expect("delete payload");
    }
    let err = spilled.try_collect(&ctx).expect_err("deleted payloads must fail");
    assert!(matches!(err, SpillError::Io { .. }), "want Io, got: {err}");
    // the error formats cleanly (what a caller would log)
    assert!(err.to_string().contains("spill"));
}

#[test]
fn temp_dir_cleaned_up_on_drop_even_on_the_error_path() {
    let ctx = Context::new(2);
    let dense = dense_fixture(&ctx);
    let store = SpillStore::with_budget(block_bytes()).expect("spill store");
    let dir = store.dir().to_path_buf();
    let spilled = dense.spill(&ctx, &store).expect("spill");
    assert!(dir.exists());

    // force the error path, then drop everything
    for path in spill_files(&store) {
        std::fs::remove_file(&path).expect("delete payload");
    }
    assert!(spilled.try_collect(&ctx).is_err());
    drop(store);
    assert!(dir.exists(), "spilled blocks still hold the store alive");
    drop(spilled);
    assert!(!dir.exists(), "spill dir must be removed with its last reference");
}

#[test]
fn sparse_tall_pipeline_recovers_exact_spectrum_through_distop() {
    // DistRowCsrMatrix as a DistOp: Algorithm 7 (which runs Algorithm 5
    // inside) end-to-end on tall sparse row slabs with an exactly
    // prescribed spectrum — and the pass ledger shows the fused rounds
    let sigma: Vec<f64> = (0..8).map(|j| 0.5f64.powi(j as i32)).collect();
    let g = SparseSpectrumTestMatrix::new(160, 48, &sigma, 0x51fb);
    let ctx = Context::new(8);
    let a = g.generate_csr_rows(&ctx, 32);
    assert_eq!(a.num_partitions(), 5);

    let iters = 2usize;
    ctx.reset_metrics();
    let out = algorithm7(&ctx, &NativeCompute, &a, &opts(8, iters));
    let m = ctx.take_metrics();
    // i fused rounds + the final sketch + Algorithm 6's B = QᵀA
    assert_eq!(m.a_passes, iters + 2, "sparse row slabs must ride the fused plan");

    assert!(out.s.len() >= 8, "rank {}", out.s.len());
    for j in 0..8 {
        assert!(
            (out.s[j] - sigma[j]).abs() / sigma[j] < 1e-10,
            "sigma_{j}: {} vs {}",
            out.s[j],
            sigma[j]
        );
    }
    let u_orth =
        dsvd::verify::max_entry_gram_minus_identity(&ctx, &NativeCompute, &out.u);
    assert!(u_orth <= 1e-13, "u_orth {u_orth}");

    // and the sparse operator verifies through the fused LinOp path:
    // one pass per verification iteration
    ctx.reset_metrics();
    let resid = dsvd::verify::ResidualOp { a: &a, u: &out.u, s: &out.s, v: &out.v };
    let recon = dsvd::verify::spectral_norm(&ctx, &resid, 10, 3);
    assert_eq!(ctx.take_metrics().a_passes, 10);
    assert!(recon < 1e-9, "recon {recon}");
}

#[test]
fn spilled_grid_exposes_its_store_and_budget() {
    let ctx = Context::new(2);
    let dense = dense_fixture(&ctx);
    let store = SpillStore::with_budget(3 * block_bytes()).expect("spill store");
    let spilled = dense.spill(&ctx, &store).expect("spill");
    let s = spilled.spill_store().expect("spilled grid has a store");
    assert_eq!(s.budget(), 3 * block_bytes());
    assert!(Arc::ptr_eq(s, &store));
    // the write ledger recorded every payload
    let (nbr, nbc) = dense.num_blocks();
    let total = nbr * nbc * block_bytes();
    assert_eq!(store.stats().bytes_written, total);
    // a second spill of the same grid pages every payload in from the
    // SOURCE store and writes it to the target — both sides metered
    let store2 = SpillStore::with_budget(usize::MAX).expect("second store");
    ctx.reset_metrics();
    let respilled = spilled.spill(&ctx, &store2).expect("respill");
    let m = ctx.take_metrics();
    assert_eq!(m.spill_bytes_written, total, "target store writes");
    assert_eq!(m.spill_bytes_read, total, "source store page-ins must be charged");
    assert_eq!(respilled.collect(&ctx).data(), dense.collect(&ctx).data());
}