//! Integration suite for the one-pass / streaming layer (ISSUE 10):
//!
//! * absorbing every slab reproduces the batch `algorithm9` run on the
//!   concatenated matrix, and both land inside the HMT error envelope
//!   around σ_{r+1} with factors orthonormal to ≤ 1e-13;
//! * the pass ledger certifies the one-pass claim — a batch run reads
//!   stored A exactly once, absorption reads each arriving slab exactly
//!   once and never re-reads absorbed rows (refresh adds zero passes);
//! * the streamed factorization is bit-deterministic across worker
//!   counts 1/2/4 under both the barrier and the pipelined scheduler;
//! * `fused_two_sided_sketch` agrees with the unfused two-call pair on
//!   every storage backend (dense / CSR / implicit / spilled blocks,
//!   dense and CSR row slabs) at half the ledger passes.

use std::f64::consts::PI;

use dsvd::algs::{algorithm9, DistSvd, StreamingOpts, StreamingSketch};
use dsvd::dist::{
    BlockStorage, CommsModel, Context, DistBlockMatrix, DistOp, DistRowCsrMatrix, DistRowMatrix,
    SchedMode, SpillStore, UnfusedOp,
};
use dsvd::gen::DctBlockTestMatrix;
use dsvd::gen::SparseRandTestMatrix;
use dsvd::linalg::qr::thin_qr;
use dsvd::linalg::{blas, Matrix};
use dsvd::rng::Rng;
use dsvd::runtime::compute::NativeCompute;
use dsvd::verify::{
    max_entry_gram_minus_identity, max_entry_gram_minus_identity_local, spectral_norm, ResidualOp,
};

fn opts(rank: usize, rows_per_part: usize) -> StreamingOpts {
    let mut o = StreamingOpts::new(rank);
    o.rows_per_part = rows_per_part;
    o
}

/// An exactly rank-`sigma.len()` m×n matrix with the given spectrum.
fn lowrank_dense(m: usize, n: usize, sigma: &[f64], seed: u64) -> Matrix {
    let mut rng = Rng::seed(seed);
    let r = sigma.len();
    let q1 = thin_qr(&Matrix::from_fn(m, r, |_, _| rng.gauss())).q;
    let q2 = thin_qr(&Matrix::from_fn(n, r, |_, _| rng.gauss())).q;
    let mut qs = q1;
    for (j, &s) in sigma.iter().enumerate() {
        qs.scale_col(j, s);
    }
    blas::matmul_nt(&qs, &q2)
}

/// `U diag(s) Vᵀ` gathered densely — a basis-independent way to compare
/// two factorizations of the same operator.
fn reconstruction(ctx: &Context, out: &DistSvd) -> Matrix {
    let mut us = out.u.collect(ctx);
    for (j, &s) in out.s.iter().enumerate() {
        us.scale_col(j, s);
    }
    blas::matmul_nt(&us, &out.v)
}

#[test]
fn streaming_matches_batch_within_hmt_envelope() {
    let ctx = Context::new(8);
    let be = NativeCompute;
    let (m, n, rank) = (96usize, 64usize, 8usize);
    // a full spectrum with a genuine tail, so the envelope gate is a
    // real statement about σ_{r+1}, not a 0 ≤ 0 tautology
    let sigma: Vec<f64> = (0..n).map(|j| 0.5f64.powi(j as i32)).collect();
    let gen = DctBlockTestMatrix::new(m, n, &sigma);
    let a = gen.generate(&ctx, &be, 32, 32);
    let dense = a.collect(&ctx);

    let (batch, batch_diag) = algorithm9(&ctx, &be, &a, &opts(rank, 16));

    // same seed, same Ω/Ψ streams — the rows just arrive in three slabs
    let mut sk = StreamingSketch::new(&ctx, n, opts(rank, 16));
    for (r0, r1) in [(0usize, 31usize), (31, 70), (70, 96)] {
        let slab = DistRowMatrix::from_matrix(&dense.slice(r0, r1, 0, n), 16);
        sk.absorb(&ctx, &be, &slab);
    }
    let (stream, stream_diag) = sk.refresh(&ctx, &be);

    // identical sketches up to floating summation order
    assert_eq!(stream.s.len(), batch.s.len());
    for j in 0..stream.s.len() {
        assert!(
            (stream.s[j] - batch.s[j]).abs() / batch.s[j] < 1e-8,
            "σ_{j}: stream {} vs batch {}",
            stream.s[j],
            batch.s[j]
        );
    }
    let d = reconstruction(&ctx, &stream).sub(&reconstruction(&ctx, &batch)).max_abs();
    assert!(d <= 1e-8, "streamed reconstruction differs from batch by {d}");
    assert_eq!(stream_diag.cross_rank, batch_diag.cross_rank);

    // HMT §10: the expected one-pass error sits within a modest factor
    // of σ_{r+1}; gate both runs on the standard envelope
    let envelope = 10.0 * (2.0 / PI).sqrt() * ((n as f64).sqrt() + 4.0) * sigma[rank];
    for (label, out) in [("batch", &batch), ("stream", &stream)] {
        let resid = ResidualOp { a: &a, u: &out.u, s: &out.s, v: &out.v };
        let err = spectral_norm(&ctx, &resid, 40, 7);
        assert!(err <= envelope, "{label}: ‖A−UΣVᵀ‖₂ = {err} > envelope {envelope}");
        let u_orth = max_entry_gram_minus_identity(&ctx, &be, &out.u);
        assert!(u_orth <= 1e-13, "{label}: MaxEntry(|UᵀU−I|) = {u_orth}");
        let v_orth = max_entry_gram_minus_identity_local(&out.v);
        assert!(v_orth <= 1e-13, "{label}: MaxEntry(|VᵀV−I|) = {v_orth}");
    }
}

#[test]
fn one_pass_ledger_on_stored_backends_and_absorption_never_rereads() {
    let ctx = Context::new(8);
    let be = NativeCompute;
    let mut rng = Rng::seed(0x57A1);
    let a = Matrix::from_fn(80, 40, |_, _| rng.gauss());

    // batch algorithm9 over stored backends: A is traversed exactly once
    let blocks = DistBlockMatrix::from_matrix(&a, 16, 16);
    ctx.reset_metrics();
    let _ = algorithm9(&ctx, &be, &blocks, &opts(5, 16));
    assert_eq!(ctx.metrics().a_passes, 1, "block storage: one traversal total");

    let csr = DistRowCsrMatrix::from_matrix(&a, 16);
    ctx.reset_metrics();
    let _ = algorithm9(&ctx, &be, &csr, &opts(5, 16));
    assert_eq!(ctx.metrics().a_passes, 1, "CSR storage: one traversal total");

    // absorption: each arriving CSR slab is read exactly once, and
    // neither later absorbs nor refresh ever touch it again
    ctx.reset_metrics();
    let mut sk = StreamingSketch::new(&ctx, 40, opts(5, 16));
    for (i, (r0, r1)) in [(0usize, 30usize), (30, 56), (56, 80)].into_iter().enumerate() {
        let slab = DistRowCsrMatrix::from_matrix(&a.slice(r0, r1, 0, 40), 16);
        sk.absorb(&ctx, &be, &slab);
        assert_eq!(ctx.metrics().a_passes, i + 1, "slab {i}: exactly one read on arrival");
        let _ = sk.refresh(&ctx, &be);
        assert_eq!(ctx.metrics().a_passes, i + 1, "refresh after slab {i} must not re-read");
    }
    let m = ctx.metrics();
    assert_eq!(m.sketch_updates, 3);
    assert_eq!(m.rows_absorbed, 80);
}

#[test]
fn streaming_is_bit_deterministic_across_workers_and_scheds() {
    const COMMS: CommsModel = CommsModel { byte_latency: 1e-4, task_overhead: 1e-3 };
    let (m, n) = (60usize, 24usize);
    let a = lowrank_dense(m, n, &[4.0, 2.0, 1.0, 0.5], 0xB17_5EED);
    let be = NativeCompute;

    type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);
    let mut reference: Option<Snapshot> = None;
    for sched in [SchedMode::Barrier, SchedMode::Pipelined] {
        for workers in [1usize, 2, 4] {
            let ctx = Context::new(8).with_workers(workers).with_comms(COMMS).with_sched(sched);
            let mut sk = StreamingSketch::new(&ctx, n, opts(4, 16));
            for (r0, r1) in [(0usize, 20usize), (20, 41), (41, 60)] {
                let slab = DistRowMatrix::from_matrix(&a.slice(r0, r1, 0, n), 16);
                sk.absorb(&ctx, &be, &slab);
            }
            let (out, _) = sk.refresh(&ctx, &be);
            let snap: Snapshot = (
                out.s.clone(),
                out.v.data().to_vec(),
                out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
            );
            match &reference {
                None => reference = Some(snap),
                Some(r) => {
                    let tag = format!("{sched:?} workers={workers}");
                    assert_eq!(&snap.0, &r.0, "{tag}: Σ changed bits");
                    assert_eq!(&snap.1, &r.1, "{tag}: V changed bits");
                    assert_eq!(&snap.2, &r.2, "{tag}: U changed bits");
                }
            }
        }
    }
}

#[test]
fn fused_two_sided_sketch_matches_unfused_on_every_backend() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xD15C);
    let ctx = Context::new(8);
    let be = NativeCompute;
    let mut rng = Rng::seed(0xD15D);
    let omega = Matrix::from_fn(64, 7, |_, _| rng.gauss());
    let psi = DistRowMatrix::from_matrix(&Matrix::from_fn(96, 11, |_, _| rng.gauss()), 32);

    // block-layout backends, including blocks spilled to disk (the
    // budget holds two of the six blocks, so the store genuinely evicts)
    let dense = g.generate(&ctx, 32, 32, BlockStorage::Dense);
    let store = SpillStore::with_budget(2 * 32 * 32 * 8).expect("spill store");
    let spilled = dense.spill(&ctx, &store).expect("spill");
    let variants: Vec<(&str, DistBlockMatrix)> = vec![
        ("dense", dense),
        ("csr", g.generate(&ctx, 32, 32, BlockStorage::SparseCsr)),
        ("implicit", g.generate(&ctx, 32, 32, BlockStorage::Implicit)),
        ("spilled", spilled),
    ];
    for (name, a) in &variants {
        let op: &dyn DistOp = a;
        let unfused = UnfusedOp(op);
        ctx.reset_metrics();
        let (yf, wf) = op.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
        let fused_passes = ctx.take_metrics().a_passes;
        ctx.reset_metrics();
        let (yu, wu) = unfused.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
        let unfused_passes = ctx.take_metrics().a_passes;
        assert_eq!(fused_passes, 1, "{name}: fused sketch must charge one pass");
        assert_eq!(unfused_passes, 2, "{name}: unfused pair charges two");
        let (yf, yu) = (yf.collect(&ctx), yu.collect(&ctx));
        if *name == "dense" || *name == "spilled" {
            // same dense per-block kernels, same fold order → exact
            assert_eq!(yf.data(), yu.data(), "{name}: Y changed bits");
            assert_eq!(wf.data(), wu.data(), "{name}: W changed bits");
        } else {
            let dy = yf.sub(&yu).max_abs();
            let dw = wf.sub(&wu).max_abs();
            assert!(dy <= 1e-12, "{name}: Y differs by {dy}");
            assert!(dw <= 1e-12, "{name}: W differs by {dw}");
        }
    }

    // row layouts: the fused slab task IS the two-call pair, fused —
    // bit-identical on both the dense and the CSR slabs
    let flat = Matrix::from_fn(96, 64, |i, j| g.entry(i, j));
    let rows = DistRowMatrix::from_matrix(&flat, 16);
    let row_op: &dyn DistOp = &rows;
    let row_unfused = UnfusedOp(row_op);
    ctx.reset_metrics();
    let (yf, wf) = row_op.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
    let (yu, wu) = row_unfused.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
    // resident dense row slabs are derived data — no ledger pass either way
    assert_eq!(ctx.take_metrics().a_passes, 0, "dense rows: derived data charges nothing");
    assert_eq!(yf.collect(&ctx).data(), yu.collect(&ctx).data(), "dense rows: Y changed bits");
    assert_eq!(wf.data(), wu.data(), "dense rows: W changed bits");

    let csr_rows = DistRowCsrMatrix::from_matrix(&flat, 16);
    let csr_op: &dyn DistOp = &csr_rows;
    let csr_unfused = UnfusedOp(csr_op);
    ctx.reset_metrics();
    let (yf, wf) = csr_op.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
    assert_eq!(ctx.take_metrics().a_passes, 1, "CSR rows: fused sketch charges one pass");
    ctx.reset_metrics();
    let (yu, wu) = csr_unfused.fused_two_sided_sketch(&ctx, &be, &omega, &psi);
    assert_eq!(ctx.take_metrics().a_passes, 2, "CSR rows: unfused pair charges two");
    assert_eq!(yf.collect(&ctx).data(), yu.collect(&ctx).data(), "CSR rows: Y changed bits");
    assert_eq!(wf.data(), wu.data(), "CSR rows: W changed bits");
}
