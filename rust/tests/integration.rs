//! Integration tests across the whole stack: coordinator invariants
//! (seeded property sweeps), cross-backend equivalence, determinism, and
//! the Appendix-A executor-scaling contract.

use dsvd::algs::{algorithm2, algorithm3, algorithm7, LowRankOpts, TallSkinnyOpts};
use dsvd::config::RunConfig;
use dsvd::dist::{tree_aggregate, tsqr, Context, DistBlockMatrix, DistRowMatrix};
use dsvd::gen::{spectrum_geometric, spectrum_lowrank, DctBlockTestMatrix, DctTestMatrix};
use dsvd::harness::{run_tall_skinny, Spectrum, TsAlg};
use dsvd::linalg::{blas, Matrix};
use dsvd::rng::Rng;
use dsvd::runtime::compute::{Compute, NativeCompute};
use dsvd::runtime::engine::PjrtCompute;

// ---------------------------------------------------------------------------
// property sweeps (seeded random shapes — poor man's proptest, no deps)
// ---------------------------------------------------------------------------

/// TSQR invariants over 24 random (m, n, rows_per_part, fan_in) draws:
/// Q orthonormal, R upper triangular, Q·R = A, shapes consistent.
#[test]
fn property_tsqr_invariants() {
    let mut meta = Rng::seed(0xBEEF);
    for case in 0..24 {
        let n = 2 + meta.below(24);
        let m = n + 1 + meta.below(400);
        let rpp = 1 + meta.below(m);
        let fan_in = 2 + meta.below(7);
        let ctx = Context::new(8).with_fan_in(fan_in);
        let mut rng = meta.split(case);
        let a = Matrix::from_fn(m, n, |_, _| rng.gauss());
        let d = DistRowMatrix::from_matrix(&a, rpp);
        let f = tsqr(&ctx, &d);
        let k = f.r.rows();
        assert!(k <= n.min(m), "case {case}: k={k} m={m} n={n}");
        // R upper triangular
        for i in 0..k {
            for j in 0..i.min(f.r.cols()) {
                assert_eq!(f.r[(i, j)], 0.0, "case {case}: R not triangular");
            }
        }
        let ql = f.q.collect(&ctx);
        let qtq = blas::matmul(&ql.transpose(), &ql);
        let orth = qtq.sub(&Matrix::eye(k)).max_abs();
        assert!(orth < 1e-12, "case {case} (m={m} n={n} rpp={rpp} fan={fan_in}): orth {orth}");
        let rec = blas::matmul(&ql, &f.r).sub(&a).max_abs();
        assert!(rec < 1e-12 * (1.0 + a.max_abs()), "case {case}: recon {rec}");
    }
}

/// treeAggregate == flat fold for random sizes, fan-ins, and executor
/// counts (the coordinator's core routing/merging invariant).
#[test]
fn property_tree_aggregate_equals_flat_fold() {
    let mut meta = Rng::seed(0xFEED);
    for case in 0..40 {
        let count = 1 + meta.below(200);
        let fan_in = 2 + meta.below(9);
        let executors = 1 + meta.below(64);
        let ctx = Context::new(executors).with_fan_in(fan_in);
        let items: Vec<u64> = (0..count).map(|_| meta.below(1000) as u64).collect();
        let want: u64 = items.iter().sum();
        let got = tree_aggregate(&ctx, items, |a, b| a + b, |_| 8).unwrap();
        assert_eq!(got, want, "case {case}: count={count} fan={fan_in}");
    }
}

/// Partition/collect roundtrip and stage-count bookkeeping over random
/// shapes (the batching/state invariant of the row-matrix layer).
#[test]
fn property_partition_roundtrip_and_metrics() {
    let mut meta = Rng::seed(0xABCD);
    for case in 0..30 {
        let m = 1 + meta.below(300);
        let n = 1 + meta.below(40);
        let rpp = 1 + meta.below(m + 4);
        let ctx = Context::new(4);
        let mut rng = meta.split(100 + case);
        let a = Matrix::from_fn(m, n, |_, _| rng.gauss());
        let d = DistRowMatrix::from_matrix(&a, rpp);
        assert_eq!(d.num_partitions(), m.div_ceil(rpp), "case {case}");
        assert_eq!(d.collect(&ctx), a, "case {case}");
        // row_starts tile [0, m) exactly
        let mut covered = 0usize;
        for p in &d.parts {
            assert_eq!(p.row_start, covered, "case {case}: partition gap");
            covered += p.data.rows();
        }
        assert_eq!(covered, m);
    }
}

/// Block-matrix products agree with dense math over random grids.
#[test]
fn property_blockmatrix_products() {
    let mut meta = Rng::seed(0xCAFE);
    for case in 0..15 {
        let m = 8 + meta.below(120);
        let n = 8 + meta.below(120);
        let rpb = 1 + meta.below(m);
        let cpb = 1 + meta.below(n);
        let l = 1 + meta.below(8);
        let ctx = Context::new(6);
        let mut rng = meta.split(200 + case);
        let a = Matrix::from_fn(m, n, |_, _| rng.gauss());
        let w = Matrix::from_fn(n, l, |_, _| rng.gauss());
        let d = DistBlockMatrix::from_matrix(&a, rpb, cpb);
        let y = d.matmul_small(&ctx, &NativeCompute, &w);
        let want = blas::matmul(&a, &w);
        assert!(
            y.collect(&ctx).sub(&want).max_abs() < 1e-11,
            "case {case} (m={m} n={n} rpb={rpb} cpb={cpb} l={l})"
        );
        let z = d.rmatmul_small(&ctx, &NativeCompute, &y);
        let want2 = blas::matmul(&a.transpose(), &want);
        assert!(z.sub(&want2).max_abs() < 1e-10, "case {case} rmatmul");
    }
}

// ---------------------------------------------------------------------------
// determinism and executor scaling
// ---------------------------------------------------------------------------

#[test]
fn same_seed_same_factorization() {
    let cfg = {
        let mut c = RunConfig::default();
        c.rows_per_part = 128;
        c
    };
    let be = NativeCompute;
    let sigma = spectrum_geometric(64);
    let make = || {
        let ctx = cfg.context();
        let a = DctTestMatrix::new(1024, 64, &sigma).generate(&ctx, &be, cfg.rows_per_part);
        let out = algorithm2(&ctx, &be, &a, &cfg.ts_opts());
        (out.s, out.v)
    };
    let (s1, v1) = make();
    let (s2, v2) = make();
    assert_eq!(s1, s2, "singular values must be bit-identical under one seed");
    assert_eq!(v1.data(), v2.data(), "V must be bit-identical under one seed");
}

/// Appendix A's contract: shrinking the cluster 10× leaves every error
/// column unchanged and CPU time comparable; only the wall-clock
/// accounting moves.
#[test]
fn executor_scaling_preserves_errors() {
    let be = NativeCompute;
    let mut rows = Vec::new();
    for executors in [180usize, 18] {
        let mut cfg = RunConfig::default();
        cfg.executors = executors;
        cfg.rows_per_part = 64;
        cfg.power_iters = 30;
        rows.push(run_tall_skinny(&cfg, &be, 1024, 64, Spectrum::Geometric, TsAlg::A2));
    }
    let (wide, narrow) = (&rows[0], &rows[1]);
    assert_eq!(wide.recon.to_bits(), narrow.recon.to_bits(), "errors must not depend on E");
    assert_eq!(wide.u_orth.to_bits(), narrow.u_orth.to_bits());
    let cpu_ratio = wide.metrics.cpu_time / narrow.metrics.cpu_time;
    assert!((0.2..5.0).contains(&cpu_ratio), "CPU should be comparable, ratio {cpu_ratio}");
}

// ---------------------------------------------------------------------------
// cross-backend equivalence (needs `make artifacts`)
// ---------------------------------------------------------------------------

#[test]
fn pjrt_and_native_agree_end_to_end() {
    let Ok(pjrt) = PjrtCompute::load_default() else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let mut cfg = RunConfig::default();
    cfg.rows_per_part = 128;
    let sigma = spectrum_geometric(64);

    let run = |be: &dyn Compute| {
        let ctx = cfg.context();
        let a = DctTestMatrix::new(512, 64, &sigma).generate(&ctx, be, cfg.rows_per_part);
        algorithm3(&ctx, be, &a, &cfg.ts_opts()).s
    };
    let s_native = run(&NativeCompute);
    let s_pjrt = run(&pjrt);
    assert_eq!(s_native.len(), s_pjrt.len());
    for (j, (a, b)) in s_native.iter().zip(&s_pjrt).enumerate() {
        assert!((a - b).abs() <= 1e-9 * a.abs().max(1e-300), "σ_{j}: {a} vs {b}");
    }
}

// ---------------------------------------------------------------------------
// failure injection / degenerate inputs
// ---------------------------------------------------------------------------

#[test]
fn degenerate_inputs_do_not_panic() {
    let cfg = {
        let mut c = RunConfig::default();
        c.rows_per_part = 8;
        c
    };
    let be = NativeCompute;
    let ctx = cfg.context();

    // constant matrix (rank 1)
    let a = DistRowMatrix::from_matrix(&Matrix::from_fn(64, 8, |_, _| 3.0), 8);
    let out = algorithm2(&ctx, &be, &a, &cfg.ts_opts());
    assert_eq!(out.s.len(), 1, "constant matrix is rank 1: {:?}", out.s);

    // single-partition, single-column
    let b = DistRowMatrix::from_matrix(&Matrix::from_fn(16, 2, |i, j| (i + j) as f64), 64);
    let out = algorithm2(&ctx, &be, &b, &cfg.ts_opts());
    assert!(!out.s.is_empty());

    // duplicated rows everywhere (numerically rank-deficient the messy way)
    let mut rng = Rng::seed(7);
    let base: Vec<f64> = (0..16).map(|_| rng.gauss()).collect();
    let c = DistRowMatrix::generate(&ctx, 128, 16, 16, |i, row| {
        let scale = 1.0 + (i % 3) as f64;
        for (j, v) in row.iter_mut().enumerate() {
            *v = base[j] * scale;
        }
    });
    let out = algorithm2(&ctx, &be, &c, &cfg.ts_opts());
    assert_eq!(out.s.len(), 1, "rank-1 by construction: {:?}", out.s);
}

#[test]
fn lowrank_rank_exceeding_structure_is_safe() {
    // ask for l = 12 of an exactly rank-4 matrix
    let ctx = Context::new(4);
    let be = NativeCompute;
    let sigma = spectrum_lowrank(64, 4);
    let sigma: Vec<f64> = sigma.iter().map(|&s| if s > 0.0 { 1.0 } else { 0.0 }).collect();
    let a = DctBlockTestMatrix::new(96, 64, &sigma).generate(&ctx, &be, 32, 32);
    let mut opts = LowRankOpts::new(12, 2);
    opts.rows_per_part = 32;
    let out = algorithm7(&ctx, &be, &a, &opts);
    // the working-precision discards must trim the rank to 4
    assert_eq!(out.s.len(), 4, "rank must collapse to 4: {:?}", out.s);
    for s in &out.s {
        assert!((s - 1.0).abs() < 1e-10);
    }
}
