//! Accuracy tests for the low-rank drivers (Algorithms 5–8) against a
//! *dense* reference SVD:
//!
//! * the spectral-norm reconstruction error of Algorithm 7 must land
//!   within the Halko–Martinsson–Tropp bound
//!   `(1 + 9·√(l·min(m,n)))^(1/(2i+1)) · σ_{l+1}` (HMT Thm 1.2 /
//!   Cor 10.10 with `i` power iterations) and can never beat the
//!   optimal `σ_{l+1}`;
//! * the double-orthonormalization variants (Algorithms 7 and 8, whose
//!   final subspace factorization runs Algorithm 2/4) must return left
//!   singular vectors with `MaxEntry(|UᵀU−I|) ≤ 1e-13` — the paper's
//!   machine-precision claim — on tall and wide shapes alike;
//! * the mixed-precision storage path (`DSVD_PRECISION=f32`: 4-byte
//!   slabs, f64 accumulation, f64 factors) must satisfy the *same* two
//!   guarantees whenever `σ_{l+1}` dwarfs the f32 demotion error — the
//!   precision-robustness that HMT (arXiv 0909.4061) establishes.

use dsvd::algs::{algorithm7, algorithm8, LowRankOpts};
use dsvd::dist::{Context, DistBlockMatrix, DistRowMatrixF32};
use dsvd::gen::DctBlockTestMatrix;
use dsvd::linalg::svd::svd;
use dsvd::linalg::{blas, Matrix};
use dsvd::runtime::compute::NativeCompute;
use dsvd::verify::{max_entry_gram_minus_identity, max_entry_gram_minus_identity_local};

/// Spectral norm of `A − U Σ Vᵀ`, computed densely (exact up to the
/// dense SVD's own roundoff — no power-method estimate involved).
fn dense_residual_norm(a: &Matrix, u: &Matrix, s: &[f64], v: &Matrix) -> f64 {
    let mut us = u.clone();
    for (j, &sj) in s.iter().enumerate() {
        us.scale_col(j, sj);
    }
    let rec = blas::matmul_nt(&us, v); // (m×k)·(n×k)ᵀ
    svd(&a.sub(&rec)).s[0]
}

fn geometric_block_matrix(
    ctx: &Context,
    m: usize,
    n: usize,
) -> (DistBlockMatrix, Matrix, Vec<f64>) {
    // full-rank spectrum σ_j = 2^−j: every truncation level is
    // meaningful and σ_{l+1} is well above roundoff for small l
    let sigma: Vec<f64> = (0..n.min(m)).map(|j| 0.5f64.powi(j as i32)).collect();
    let gen = DctBlockTestMatrix::new(m, n, &sigma);
    let a = gen.generate(ctx, &NativeCompute, 16, 16);
    let a_dense = a.collect(ctx);
    (a, a_dense, sigma)
}

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 16;
    o
}

#[test]
fn dense_reference_confirms_designed_spectrum() {
    // the DCT test-matrix generator must deliver the singular values it
    // promises — otherwise the bounds below test nothing
    let ctx = Context::new(4);
    let (_a, a_dense, sigma) = geometric_block_matrix(&ctx, 80, 48);
    let reference = svd(&a_dense);
    for j in 0..12 {
        assert!(
            (reference.s[j] - sigma[j]).abs() <= 1e-10 * sigma[0],
            "σ_{j}: dense {} vs designed {}",
            reference.s[j],
            sigma[j]
        );
    }
}

#[test]
fn algorithm7_within_hmt_bound_of_dense_reference() {
    let (m, n, l, iters) = (80usize, 48usize, 6usize, 2usize);
    let ctx = Context::new(8);
    let (a, a_dense, _) = geometric_block_matrix(&ctx, m, n);
    let reference = svd(&a_dense);
    let sigma_opt = reference.s[l]; // σ_{l+1}: the optimal rank-l error

    let out = algorithm7(&ctx, &NativeCompute, &a, &opts(l, iters));
    let u_dense = out.u.collect(&ctx);
    let err = dense_residual_norm(&a_dense, &u_dense, &out.s, &out.v);

    // HMT-style bound with i power iterations
    let factor = (1.0 + 9.0 * ((l * n.min(m)) as f64).sqrt())
        .powf(1.0 / (2.0 * iters as f64 + 1.0));
    assert!(
        err <= factor * sigma_opt,
        "‖A−UΣVᵀ‖₂ = {err} exceeds HMT bound {} (= {factor:.3}·σ_l+1)",
        factor * sigma_opt
    );
    // no rank-l approximation beats the optimal truncation
    assert!(err >= 0.999 * sigma_opt, "err {err} below the optimal {sigma_opt}");

    // top singular values agree with the dense reference
    for j in 0..3 {
        let rel = (out.s[j] - reference.s[j]).abs() / reference.s[j];
        assert!(rel < 1e-6, "σ_{j}: {} vs dense {} (rel {rel})", out.s[j], reference.s[j]);
    }
}

#[test]
fn algorithm8_within_hmt_bound_of_dense_reference() {
    // the Gram engine loses half the digits on reconstruction (Table
    // 10's contrast) but σ_{l+1} = 2^−6 dwarfs that loss here, so the
    // same HMT bound must hold
    let (m, n, l, iters) = (80usize, 48usize, 6usize, 2usize);
    let ctx = Context::new(8);
    let (a, a_dense, _) = geometric_block_matrix(&ctx, m, n);
    let reference = svd(&a_dense);
    let sigma_opt = reference.s[l];

    let out = algorithm8(&ctx, &NativeCompute, &a, &opts(l, iters));
    let u_dense = out.u.collect(&ctx);
    let err = dense_residual_norm(&a_dense, &u_dense, &out.s, &out.v);
    let factor = (1.0 + 9.0 * ((l * n.min(m)) as f64).sqrt())
        .powf(1.0 / (2.0 * iters as f64 + 1.0));
    assert!(err <= factor * sigma_opt, "err {err} vs bound {}", factor * sigma_opt);
    assert!(err >= 0.999 * sigma_opt, "err {err} below the optimal {sigma_opt}");
}

#[test]
fn f32_sketch_path_stays_within_hmt_envelope() {
    // The f32 storage path demotes only the *input* slabs: every product
    // widens to f64 on read and the sketch/TSQR/SVD stages never leave
    // f64. The demotion perturbs A by ‖E‖₂ ≲ √(mn)·ε_f32·max|aᵢⱼ| ≈ 1e-5
    // here, far below σ_{l+1} = 2⁻⁶, so both the HMT reconstruction
    // bound and the machine-precision orthonormality claim must survive
    // the 4-byte operand untouched.
    let (m, n, l, iters) = (80usize, 48usize, 6usize, 2usize);
    let ctx = Context::new(8);
    let (_a, a_dense, _) = geometric_block_matrix(&ctx, m, n);
    let reference = svd(&a_dense);
    let sigma_opt = reference.s[l];
    let factor = (1.0 + 9.0 * ((l * n.min(m)) as f64).sqrt())
        .powf(1.0 / (2.0 * iters as f64 + 1.0));

    let a32 = DistRowMatrixF32::from_matrix(&a_dense, 16);
    assert_eq!(a32.storage_bytes(), 4 * m * n, "f32 slabs must charge 4 bytes/entry");
    for (name, out) in [
        ("algorithm7", algorithm7(&ctx, &NativeCompute, &a32, &opts(l, iters))),
        ("algorithm8", algorithm8(&ctx, &NativeCompute, &a32, &opts(l, iters))),
    ] {
        // reconstruction error measured against the *original* f64 A
        let u_dense = out.u.collect(&ctx);
        let err = dense_residual_norm(&a_dense, &u_dense, &out.s, &out.v);
        assert!(
            err <= factor * sigma_opt,
            "{name} on f32 slabs: ‖A−UΣVᵀ‖₂ = {err} exceeds HMT bound {}",
            factor * sigma_opt
        );
        assert!(err >= 0.999 * sigma_opt, "{name}: err {err} below the optimal {sigma_opt}");
        // the factors are pure f64 products of f64 orthonormalizations,
        // so the paper's 1e-13 claim must hold bit-for-bit as in f64
        let u_orth = max_entry_gram_minus_identity(&ctx, &NativeCompute, &out.u);
        assert!(u_orth <= 1e-13, "{name} (f32 path): MaxEntry(|UᵀU−I|) = {u_orth} > 1e-13");
        let v_orth = max_entry_gram_minus_identity_local(&out.v);
        assert!(v_orth <= 1e-13, "{name} (f32 path): MaxEntry(|VᵀV−I|) = {v_orth} > 1e-13");
        // top singular values are insensitive to the 4-byte operand
        for j in 0..3 {
            let rel = (out.s[j] - reference.s[j]).abs() / reference.s[j];
            assert!(rel < 1e-5, "{name} σ_{j}: {} vs dense {}", out.s[j], reference.s[j]);
        }
    }
}

#[test]
fn double_orthonormalization_hits_machine_precision() {
    // MaxEntry(|UᵀU−I|) ≤ 1e-13 for BOTH double-orthonormalization
    // engines, on a tall and a wide shape
    for (m, n, l) in [(96usize, 64usize, 8usize), (48, 96, 5)] {
        let ctx = Context::new(8);
        let sigma: Vec<f64> = (0..n.min(m)).map(|j| 0.5f64.powi(j as i32)).collect();
        let a = DctBlockTestMatrix::new(m, n, &sigma).generate(&ctx, &NativeCompute, 16, 16);
        for (name, out) in [
            ("algorithm7", algorithm7(&ctx, &NativeCompute, &a, &opts(l, 2))),
            ("algorithm8", algorithm8(&ctx, &NativeCompute, &a, &opts(l, 2))),
        ] {
            let u_orth = max_entry_gram_minus_identity(&ctx, &NativeCompute, &out.u);
            assert!(
                u_orth <= 1e-13,
                "{name} ({m}x{n}): MaxEntry(|UᵀU−I|) = {u_orth} > 1e-13"
            );
            let v_orth = max_entry_gram_minus_identity_local(&out.v);
            assert!(
                v_orth <= 1e-13,
                "{name} ({m}x{n}): MaxEntry(|VᵀV−I|) = {v_orth} > 1e-13"
            );
        }
    }
}
