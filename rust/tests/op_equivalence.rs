//! Property suite over the `Block` storage backends of the DistOp
//! layer: for every backend (dense / per-block CSR / implicit),
//! Algorithms 7 and 8 must return the same factorization as a run over
//! the densified reference matrix to within 1e-12, with both factors
//! orthonormal to ≤ 1e-13 — and the dense backend must stay
//! bit-identical across worker counts 1/2/4 (the PR-2 determinism
//! guarantee carried through the refactor: the dense per-block kernels
//! and fold orders are untouched, so for grids no deeper than the
//! fan-in the dense path is the pre-refactor computation instruction
//! for instruction).

use dsvd::algs::{algorithm7, algorithm8, DistSvd, LowRankOpts};
use dsvd::dist::{
    BlockStorage, Context, DistBlockMatrix, DistOp, DistRowCsrMatrix, DistRowMatrix, UnfusedOp,
};
use dsvd::gen::{SparseRandTestMatrix, SparseSpectrumTestMatrix};
use dsvd::linalg::{blas, Matrix};
use dsvd::rng::Rng;
use dsvd::runtime::compute::NativeCompute;
use dsvd::verify::{
    max_entry_gram_minus_identity, max_entry_gram_minus_identity_local, spectral_norm, ResidualOp,
};

const BACKENDS: [(&str, BlockStorage); 3] = [
    ("dense", BlockStorage::Dense),
    ("csr", BlockStorage::SparseCsr),
    ("implicit", BlockStorage::Implicit),
];

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 32;
    o
}

/// `U diag(s) Vᵀ` gathered densely — a basis-independent way to compare
/// two factorizations of the same operator.
fn reconstruction(ctx: &Context, out: &DistSvd) -> Matrix {
    let mut us = out.u.collect(ctx);
    for (j, &s) in out.s.iter().enumerate() {
        us.scale_col(j, s);
    }
    blas::matmul_nt(&us, &out.v)
}

fn assert_matches_reference(label: &str, ctx: &Context, out: &DistSvd, reference: &DistSvd) {
    assert_eq!(out.s.len(), reference.s.len(), "{label}: rank mismatch");
    let scale = reference.s.first().copied().unwrap_or(1.0).max(1.0);
    for (j, (a, b)) in out.s.iter().zip(&reference.s).enumerate() {
        assert!((a - b).abs() <= 1e-12 * scale, "{label}: σ_{j} {a} vs {b}");
    }
    let d = reconstruction(ctx, out).sub(&reconstruction(ctx, reference)).max_abs();
    assert!(d <= 1e-12 * scale, "{label}: reconstruction differs by {d}");
}

#[test]
fn every_backend_matches_the_densified_reference() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x0E0);
    let ctx = Context::new(8);
    let be = NativeCompute;
    for (name, storage) in BACKENDS {
        let a = g.generate(&ctx, 32, 32, storage);
        let reference = a.densify(&ctx);
        for (alg_name, out, want) in [
            (
                "alg7",
                algorithm7(&ctx, &be, &a, &opts(8, 2)),
                algorithm7(&ctx, &be, &reference, &opts(8, 2)),
            ),
            (
                "alg8",
                algorithm8(&ctx, &be, &a, &opts(8, 2)),
                algorithm8(&ctx, &be, &reference, &opts(8, 2)),
            ),
        ] {
            let label = format!("{name}/{alg_name}");
            assert_matches_reference(&label, &ctx, &out, &want);
            let u_orth = max_entry_gram_minus_identity(&ctx, &be, &out.u);
            assert!(u_orth <= 1e-13, "{label}: MaxEntry(|UᵀU−I|) = {u_orth}");
            let v_orth = max_entry_gram_minus_identity_local(&out.v);
            assert!(v_orth <= 1e-13, "{label}: MaxEntry(|VᵀV−I|) = {v_orth}");
        }
    }
}

#[test]
fn sparse_backends_recover_an_exact_spectrum() {
    // permutation-scaled input: singular values exactly σ, genuinely
    // sparse (one nonzero per used row/column) — the accuracy face of
    // the CSR and implicit backends
    let sigma: Vec<f64> = (0..10).map(|j| 0.5f64.powi(j as i32)).collect();
    let g = SparseSpectrumTestMatrix::new(128, 96, &sigma, 0x51fa);
    let ctx = Context::new(8);
    let be = NativeCompute;
    for (name, storage) in BACKENDS {
        let a = g.generate(&ctx, 32, 32, storage);
        let out = algorithm7(&ctx, &be, &a, &opts(10, 2));
        assert!(out.s.len() >= 10, "{name}: rank {}", out.s.len());
        for j in 0..10 {
            assert!(
                (out.s[j] - sigma[j]).abs() / sigma[j] < 1e-10,
                "{name}: σ_{j} {} vs {}",
                out.s[j],
                sigma[j]
            );
        }
    }
}

#[test]
fn dense_backend_bit_identical_across_worker_counts() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xB17);
    type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);
    let snapshot = |out: &DistSvd| -> Snapshot {
        (
            out.s.clone(),
            out.v.data().to_vec(),
            out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
        )
    };
    for alg in ["alg7", "alg8"] {
        let mut reference: Option<Snapshot> = None;
        for workers in [1usize, 2, 4] {
            let ctx = Context::new(8).with_workers(workers);
            let a: DistBlockMatrix = g.generate(&ctx, 32, 32, BlockStorage::Dense);
            let out = match alg {
                "alg7" => algorithm7(&ctx, &NativeCompute, &a, &opts(8, 2)),
                _ => algorithm8(&ctx, &NativeCompute, &a, &opts(8, 2)),
            };
            let snap = snapshot(&out);
            match &reference {
                None => reference = Some(snap),
                Some(r) => {
                    assert_eq!(&snap.0, &r.0, "{alg} workers={workers}: Σ changed bits");
                    assert_eq!(&snap.1, &r.1, "{alg} workers={workers}: V changed bits");
                    assert_eq!(&snap.2, &r.2, "{alg} workers={workers}: U changed bits");
                }
            }
        }
    }
}

#[test]
fn fused_step_matches_two_call_per_backend() {
    // the operator-level contract of the fused layer: for the dense
    // backend `fused_power_step` is bit-identical to the
    // `matmul_small` + `rmatmul_small` pair for every worker count;
    // CSR and implicit agree to ≤ 1e-12 (in practice they too are
    // bit-identical — same kernels, same fold order)
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xF0D);
    let mut rng = Rng::seed(0xF0D1);
    let w = Matrix::from_fn(64, 5, |_, _| rng.gauss());
    let mut dense_snapshot: Option<(Vec<f64>, Vec<f64>)> = None;
    for workers in [1usize, 2, 4] {
        let ctx = Context::new(8).with_workers(workers);
        let be = NativeCompute;
        for (name, storage) in BACKENDS {
            let a = g.generate(&ctx, 32, 32, storage);
            let (y_f, z_f) = a.fused_power_step(&ctx, &be, &w);
            let y_u = a.matmul_small(&ctx, &be, &w);
            let z_u = a.rmatmul_small(&ctx, &be, &y_u);
            let y_f = y_f.collect(&ctx);
            let y_u = y_u.collect(&ctx);
            if storage == BlockStorage::Dense {
                assert_eq!(y_f.data(), y_u.data(), "dense Y, workers={workers}");
                assert_eq!(z_f.data(), z_u.data(), "dense Z, workers={workers}");
                match &dense_snapshot {
                    None => dense_snapshot = Some((y_f.data().to_vec(), z_f.data().to_vec())),
                    Some((y_ref, z_ref)) => {
                        assert_eq!(y_f.data(), &y_ref[..], "dense Y drifted, workers={workers}");
                        assert_eq!(z_f.data(), &z_ref[..], "dense Z drifted, workers={workers}");
                    }
                }
            } else {
                let dy = y_f.sub(&y_u).max_abs();
                let dz = z_f.sub(&z_u).max_abs();
                assert!(dy <= 1e-12, "{name} Y differs by {dy}, workers={workers}");
                assert!(dz <= 1e-12, "{name} Z differs by {dz}, workers={workers}");
            }
        }
    }
}

#[test]
fn fused_loop_halves_implicit_passes() {
    // the measurable heart of the fused layer: a full Algorithm 7 run
    // reads the implicit operator q+2 times fused vs 2q+2 unfused —
    // i.e. one generator run per cell per power round instead of two —
    // at bit-identical results (the fused step IS the two-call pair,
    // fused)
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xAB5);
    let ctx = Context::new(8);
    let a = g.generate(&ctx, 32, 32, BlockStorage::Implicit);
    let (nbr, nbc) = a.num_blocks();
    let cells = nbr * nbc;
    let iters = 2usize;

    ctx.reset_metrics();
    let fused = algorithm7(&ctx, &NativeCompute, &a, &opts(8, iters));
    let mf = ctx.take_metrics();

    ctx.reset_metrics();
    let unfused = algorithm7(&ctx, &NativeCompute, &UnfusedOp(&a), &opts(8, iters));
    let mu = ctx.take_metrics();

    assert_eq!(mf.a_passes, iters + 2, "fused passes");
    assert_eq!(mu.a_passes, 2 * iters + 2, "unfused passes");
    assert_eq!(mf.blocks_materialized, (iters + 2) * cells, "fused generator runs");
    assert_eq!(mu.blocks_materialized, (2 * iters + 2) * cells, "unfused generator runs");

    assert_eq!(fused.s, unfused.s, "Σ must not change under fusion");
    assert_eq!(fused.v.data(), unfused.v.data(), "V must not change under fusion");
    for (pf, pu) in fused.u.parts.iter().zip(&unfused.u.parts) {
        assert_eq!(pf.data.data(), pu.data.data(), "U must not change under fusion");
    }
}

#[test]
fn residual_verification_reads_a_once_per_iteration() {
    // the fused-verifier item: spectral-norm verification of a
    // factorization drives the residual through ONE A traversal per
    // power iteration (`fused_normal_matvec_sub` carries the factor
    // correction inside the pass), where the pre-fusion plan issued the
    // matvec/rmatvec pair — at a bit-identical estimate. The UnfusedOp
    // wrapper restores the two-pass plan for the comparison.
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x0E1);
    let ctx = Context::new(8);
    let be = NativeCompute;
    let a = g.generate(&ctx, 32, 32, BlockStorage::Dense);
    let out = algorithm7(&ctx, &be, &a, &opts(8, 2));
    let iters = 6usize;

    let op: &dyn DistOp = &a;
    ctx.reset_metrics();
    let resid = ResidualOp { a: &op, u: &out.u, s: &out.s, v: &out.v };
    let fused_est = spectral_norm(&ctx, &resid, iters, 9);
    let mf = ctx.take_metrics();
    assert_eq!(mf.a_passes, iters, "fused verification: one A pass per iteration");

    let unfused = UnfusedOp(&a);
    let uop: &dyn DistOp = &unfused;
    ctx.reset_metrics();
    let resid_u = ResidualOp { a: &uop, u: &out.u, s: &out.s, v: &out.v };
    let unfused_est = spectral_norm(&ctx, &resid_u, iters, 9);
    let mu = ctx.take_metrics();
    assert_eq!(mu.a_passes, 2 * iters, "unfused verification: two A passes per iteration");

    assert_eq!(
        fused_est.to_bits(),
        unfused_est.to_bits(),
        "fusing the verifier must not change the estimate: {fused_est} vs {unfused_est}"
    );
}

#[test]
fn csr_slab_batch_products_pinned_to_defaults() {
    // the tall-sparse batch overrides: `DistRowCsrMatrix` serves k
    // factors from ONE sweep of the CSR arrays (one ledger pass), and
    // must stay bit-identical to the per-factor trait defaults — which
    // `UnfusedOp` deliberately keeps, making it the baseline here just
    // as it is for the fused-step pins above.
    let mut rng = Rng::seed(0xBA7C);
    let a =
        Matrix::from_fn(70, 12, |_, _| if rng.uniform() < 0.25 { rng.gauss() } else { 0.0 });
    let d = DistRowCsrMatrix::from_matrix(&a, 9); // 8 slabs
    let ctx = Context::new(8);
    let be = NativeCompute;
    let op: &dyn DistOp = &d;
    let unfused = UnfusedOp(&d);
    let base: &dyn DistOp = &unfused;

    // ragged factor widths so per-factor bookkeeping can't hide behind
    // a uniform shape
    let ws: Vec<Matrix> = [2usize, 5, 3]
        .iter()
        .enumerate()
        .map(|(j, &k)| {
            let mut r = Rng::seed(0xBA7D + j as u64);
            Matrix::from_fn(12, k, |_, _| r.gauss())
        })
        .collect();

    ctx.reset_metrics();
    let got = op.matmul_small_batch(&ctx, &be, &ws);
    let m_batch = ctx.take_metrics();
    ctx.reset_metrics();
    let want = base.matmul_small_batch(&ctx, &be, &ws);
    let m_default = ctx.take_metrics();
    assert_eq!(m_batch.a_passes, 1, "batched A·Wₖ must charge one pass for k factors");
    assert_eq!(m_default.a_passes, ws.len(), "default charges one pass per factor");
    assert_eq!(got.len(), want.len());
    for (f, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.parts.len(), w.parts.len(), "factor {f}: partitioning changed");
        for (pg, pw) in g.parts.iter().zip(&w.parts) {
            assert_eq!(pg.row_start, pw.row_start, "factor {f}: slab layout changed");
            assert_eq!(pg.data.data(), pw.data.data(), "factor {f}: A·W changed bits");
        }
    }

    let qs_owned: Vec<DistRowMatrix> = (0..3usize)
        .map(|j| {
            let mut r = Rng::seed(0xC0DE + j as u64);
            DistRowMatrix::from_matrix(&Matrix::from_fn(70, 2 + j, |_, _| r.gauss()), 13)
        })
        .collect();
    let qs: Vec<&DistRowMatrix> = qs_owned.iter().collect();

    ctx.reset_metrics();
    let got = op.rmatmul_small_batch(&ctx, &be, &qs);
    assert_eq!(
        ctx.take_metrics().a_passes,
        1,
        "batched Aᵀ·Qₖ must charge one pass for k factors"
    );
    ctx.reset_metrics();
    let want = base.rmatmul_small_batch(&ctx, &be, &qs);
    assert_eq!(ctx.take_metrics().a_passes, qs.len(), "default charges one pass per factor");
    assert_eq!(got.len(), want.len());
    for (f, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.data(), w.data(), "factor {f}: Aᵀ·Q changed bits");
    }

    // degenerate batches stay cheap and well-formed
    ctx.reset_metrics();
    assert!(op.matmul_small_batch(&ctx, &be, &[]).is_empty());
    assert!(op.rmatmul_small_batch(&ctx, &be, &[]).is_empty());
    assert_eq!(ctx.take_metrics().a_passes, 0, "empty batches must not touch A");
}

#[test]
fn implicit_backend_is_bit_identical_to_dense() {
    // implicit cells materialize the very same dense blocks inside the
    // consuming tasks, so the whole factorization matches to the bit
    let g = SparseRandTestMatrix::new(64, 48, 0.3, 0x1A);
    let ctx = Context::new(4);
    let dense = g.generate(&ctx, 16, 16, BlockStorage::Dense);
    let imp = g.generate(&ctx, 16, 16, BlockStorage::Implicit);
    let a = algorithm7(&ctx, &NativeCompute, &dense, &opts(6, 1));
    let b = algorithm7(&ctx, &NativeCompute, &imp, &opts(6, 1));
    assert_eq!(a.s, b.s);
    assert_eq!(a.v.data(), b.v.data());
    for (pa, pb) in a.u.parts.iter().zip(&b.u.parts) {
        assert_eq!(pa.data.data(), pb.data.data());
    }
}
