//! Property suite over the `Block` storage backends of the DistOp
//! layer: for every backend (dense / per-block CSR / implicit),
//! Algorithms 7 and 8 must return the same factorization as a run over
//! the densified reference matrix to within 1e-12, with both factors
//! orthonormal to ≤ 1e-13 — and the dense backend must stay
//! bit-identical across worker counts 1/2/4 (the PR-2 determinism
//! guarantee carried through the refactor: the dense per-block kernels
//! and fold orders are untouched, so for grids no deeper than the
//! fan-in the dense path is the pre-refactor computation instruction
//! for instruction).

use dsvd::algs::{algorithm7, algorithm8, DistSvd, LowRankOpts};
use dsvd::dist::{BlockStorage, Context, DistBlockMatrix};
use dsvd::gen::{SparseRandTestMatrix, SparseSpectrumTestMatrix};
use dsvd::linalg::{blas, Matrix};
use dsvd::runtime::compute::NativeCompute;
use dsvd::verify::{max_entry_gram_minus_identity, max_entry_gram_minus_identity_local};

const BACKENDS: [(&str, BlockStorage); 3] = [
    ("dense", BlockStorage::Dense),
    ("csr", BlockStorage::SparseCsr),
    ("implicit", BlockStorage::Implicit),
];

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 32;
    o
}

/// `U diag(s) Vᵀ` gathered densely — a basis-independent way to compare
/// two factorizations of the same operator.
fn reconstruction(ctx: &Context, out: &DistSvd) -> Matrix {
    let mut us = out.u.collect(ctx);
    for (j, &s) in out.s.iter().enumerate() {
        us.scale_col(j, s);
    }
    blas::matmul_nt(&us, &out.v)
}

fn assert_matches_reference(label: &str, ctx: &Context, out: &DistSvd, reference: &DistSvd) {
    assert_eq!(out.s.len(), reference.s.len(), "{label}: rank mismatch");
    let scale = reference.s.first().copied().unwrap_or(1.0).max(1.0);
    for (j, (a, b)) in out.s.iter().zip(&reference.s).enumerate() {
        assert!((a - b).abs() <= 1e-12 * scale, "{label}: σ_{j} {a} vs {b}");
    }
    let d = reconstruction(ctx, out).sub(&reconstruction(ctx, reference)).max_abs();
    assert!(d <= 1e-12 * scale, "{label}: reconstruction differs by {d}");
}

#[test]
fn every_backend_matches_the_densified_reference() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x0E0);
    let ctx = Context::new(8);
    let be = NativeCompute;
    for (name, storage) in BACKENDS {
        let a = g.generate(&ctx, 32, 32, storage);
        let reference = a.densify(&ctx);
        for (alg_name, out, want) in [
            (
                "alg7",
                algorithm7(&ctx, &be, &a, &opts(8, 2)),
                algorithm7(&ctx, &be, &reference, &opts(8, 2)),
            ),
            (
                "alg8",
                algorithm8(&ctx, &be, &a, &opts(8, 2)),
                algorithm8(&ctx, &be, &reference, &opts(8, 2)),
            ),
        ] {
            let label = format!("{name}/{alg_name}");
            assert_matches_reference(&label, &ctx, &out, &want);
            let u_orth = max_entry_gram_minus_identity(&ctx, &be, &out.u);
            assert!(u_orth <= 1e-13, "{label}: MaxEntry(|UᵀU−I|) = {u_orth}");
            let v_orth = max_entry_gram_minus_identity_local(&out.v);
            assert!(v_orth <= 1e-13, "{label}: MaxEntry(|VᵀV−I|) = {v_orth}");
        }
    }
}

#[test]
fn sparse_backends_recover_an_exact_spectrum() {
    // permutation-scaled input: singular values exactly σ, genuinely
    // sparse (one nonzero per used row/column) — the accuracy face of
    // the CSR and implicit backends
    let sigma: Vec<f64> = (0..10).map(|j| 0.5f64.powi(j as i32)).collect();
    let g = SparseSpectrumTestMatrix::new(128, 96, &sigma, 0x51fa);
    let ctx = Context::new(8);
    let be = NativeCompute;
    for (name, storage) in BACKENDS {
        let a = g.generate(&ctx, 32, 32, storage);
        let out = algorithm7(&ctx, &be, &a, &opts(10, 2));
        assert!(out.s.len() >= 10, "{name}: rank {}", out.s.len());
        for j in 0..10 {
            assert!(
                (out.s[j] - sigma[j]).abs() / sigma[j] < 1e-10,
                "{name}: σ_{j} {} vs {}",
                out.s[j],
                sigma[j]
            );
        }
    }
}

#[test]
fn dense_backend_bit_identical_across_worker_counts() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xB17);
    type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);
    let snapshot = |out: &DistSvd| -> Snapshot {
        (
            out.s.clone(),
            out.v.data().to_vec(),
            out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
        )
    };
    for alg in ["alg7", "alg8"] {
        let mut reference: Option<Snapshot> = None;
        for workers in [1usize, 2, 4] {
            let ctx = Context::new(8).with_workers(workers);
            let a: DistBlockMatrix = g.generate(&ctx, 32, 32, BlockStorage::Dense);
            let out = match alg {
                "alg7" => algorithm7(&ctx, &NativeCompute, &a, &opts(8, 2)),
                _ => algorithm8(&ctx, &NativeCompute, &a, &opts(8, 2)),
            };
            let snap = snapshot(&out);
            match &reference {
                None => reference = Some(snap),
                Some(r) => {
                    assert_eq!(&snap.0, &r.0, "{alg} workers={workers}: Σ changed bits");
                    assert_eq!(&snap.1, &r.1, "{alg} workers={workers}: V changed bits");
                    assert_eq!(&snap.2, &r.2, "{alg} workers={workers}: U changed bits");
                }
            }
        }
    }
}

#[test]
fn implicit_backend_is_bit_identical_to_dense() {
    // implicit cells materialize the very same dense blocks inside the
    // consuming tasks, so the whole factorization matches to the bit
    let g = SparseRandTestMatrix::new(64, 48, 0.3, 0x1A);
    let ctx = Context::new(4);
    let dense = g.generate(&ctx, 16, 16, BlockStorage::Dense);
    let imp = g.generate(&ctx, 16, 16, BlockStorage::Implicit);
    let a = algorithm7(&ctx, &NativeCompute, &dense, &opts(6, 1));
    let b = algorithm7(&ctx, &NativeCompute, &imp, &opts(6, 1));
    assert_eq!(a.s, b.s);
    assert_eq!(a.v.data(), b.v.data());
    for (pa, pb) in a.u.parts.iter().zip(&b.u.parts) {
        assert_eq!(pa.data.data(), pb.data.data());
    }
}
