//! End-to-end fault-tolerance suite: the recovery invariant of the
//! `dist::fault` layer, exercised through the paper's algorithms.
//!
//! * Under a seeded schedule of injected panics, transient I/O and
//!   corruption errors, and stragglers, Algorithms 2/7/8 recover and
//!   return factors **bit-identical** to a fault-free run — on every
//!   storage backend (dense / CSR / implicit / spilled) and every
//!   worker count, with the retry counters proving faults actually
//!   fired and were survived.
//! * A persistent fault exhausts the retry budget and surfaces as a
//!   typed [`DsvdError`] through the algorithm `try_*` surfaces —
//!   never a raw panic, and never silent wrong numbers.
//! * A run killed mid-flight leaks no spill temp directories.
//! * The stage-boundary [`HealthCheck`] catches the paper's
//!   silent-wrong-answer failure — the stock `computeSVD` baseline
//!   returning a badly non-orthonormal U — as a typed error, while the
//!   cured pipeline (Algorithm 2) passes the same guard.

use dsvd::algs::{
    algorithm2, algorithm7, algorithm8, try_algorithm2, try_algorithm7, try_preexisting,
    DistSvd, LowRankOpts, TallSkinnyOpts,
};
use dsvd::dist::{
    BlockStorage, Context, DistBlockMatrix, DistRowMatrix, DsvdError, FaultKind, FaultPlan,
    HealthCheck, RetryPolicy, SpillStore,
};
use dsvd::gen::{spectrum_geometric, DctTestMatrix, SparseRandTestMatrix};
use dsvd::linalg::Matrix;
use dsvd::rng::Rng;
use dsvd::runtime::compute::NativeCompute;

const BACKENDS: [(&str, BlockStorage); 3] = [
    ("dense", BlockStorage::Dense),
    ("csr", BlockStorage::SparseCsr),
    ("implicit", BlockStorage::Implicit),
];

/// A seeded random schedule plus one guaranteed recoverable fault at
/// stage 1 (every run here has a stage 1), so each faulted run
/// provably retries and recovers at least once whatever the random
/// draws do.
fn plan() -> FaultPlan {
    FaultPlan::seeded(0xFA01, 0.3)
        .with_straggle_delay(0.5)
        .with_target(1, 0, FaultKind::TransientIo)
}

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 32;
    o
}

type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);

fn snap(out: &DistSvd) -> Snapshot {
    (
        out.s.clone(),
        out.v.data().to_vec(),
        out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
    )
}

/// The retry counters that prove a faulted run actually survived
/// something: faults fired, tasks were retried, retries recovered.
fn assert_survived(label: &str, ctx: &Context) {
    let m = ctx.metrics();
    assert!(m.faults_injected >= 1, "{label}: no faults injected");
    assert!(m.tasks_retried >= 1, "{label}: nothing retried");
    assert!(m.recoveries >= 1, "{label}: nothing recovered");
}

#[test]
fn algorithm2_recovers_bit_identically_across_workers() {
    let sigma = spectrum_geometric(32);
    let gen = DctTestMatrix::new(256, 32, &sigma);
    let ts = TallSkinnyOpts::default();
    for workers in [1usize, 2, 4] {
        let free = Context::new(8).with_workers(workers);
        let a = gen.generate(&free, &NativeCompute, 32);
        let want = snap(&algorithm2(&free, &NativeCompute, &a, &ts));

        let ctx = Context::new(8).with_workers(workers).with_fault_plan(plan());
        let a = gen.generate(&ctx, &NativeCompute, 32);
        let got = snap(&algorithm2(&ctx, &NativeCompute, &a, &ts));
        assert_eq!(got, want, "alg2 workers={workers}: recovered run changed bits");
        assert_survived(&format!("alg2 workers={workers}"), &ctx);
    }
}

#[test]
fn algorithms_7_and_8_recover_bit_identically_on_every_backend() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xFA2);
    for (name, storage) in BACKENDS {
        for workers in [1usize, 2, 4] {
            let free = Context::new(8).with_workers(workers);
            let a = g.generate(&free, 32, 32, storage);
            let want7 = snap(&algorithm7(&free, &NativeCompute, &a, &opts(8, 2)));
            let want8 = snap(&algorithm8(&free, &NativeCompute, &a, &opts(8, 2)));

            let ctx = Context::new(8).with_workers(workers).with_fault_plan(plan());
            let a = g.generate(&ctx, 32, 32, storage);
            let got7 = snap(&algorithm7(&ctx, &NativeCompute, &a, &opts(8, 2)));
            let got8 = snap(&algorithm8(&ctx, &NativeCompute, &a, &opts(8, 2)));
            assert_eq!(got7, want7, "{name}/alg7 workers={workers} changed bits");
            assert_eq!(got8, want8, "{name}/alg8 workers={workers} changed bits");
            assert_survived(&format!("{name} workers={workers}"), &ctx);
        }
    }
}

#[test]
fn spilled_backend_recovers_bit_identically() {
    // the out-of-core tier under the same schedule: page-cache traffic
    // and injected faults compose without changing a bit
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xFA3);
    let block_bytes = 8 * 32 * 32;
    for workers in [1usize, 2, 4] {
        let free = Context::new(8).with_workers(workers);
        let dense: DistBlockMatrix = g.generate(&free, 32, 32, BlockStorage::Dense);
        let store = SpillStore::with_budget(4 * block_bytes).expect("spill store");
        let spilled = dense.spill(&free, &store).expect("spill");
        let want = snap(&algorithm7(&free, &NativeCompute, &spilled, &opts(8, 2)));

        let ctx = Context::new(8).with_workers(workers).with_fault_plan(plan());
        let dense: DistBlockMatrix = g.generate(&ctx, 32, 32, BlockStorage::Dense);
        let store = SpillStore::with_budget(4 * block_bytes).expect("spill store");
        let dir = store.dir().to_path_buf();
        let spilled = dense.spill(&ctx, &store).expect("spill");
        // the typed surface: under a recoverable schedule it returns Ok
        // (and its health guards pass) with the identical factors
        let got = snap(
            &try_algorithm7(&ctx, &NativeCompute, &spilled, &opts(8, 2), &HealthCheck::default())
                .expect("a recoverable schedule must come back Ok"),
        );
        assert_eq!(got, want, "spilled/alg7 workers={workers} changed bits");
        assert_survived(&format!("spilled workers={workers}"), &ctx);

        drop(spilled);
        drop(store);
        assert!(!dir.exists(), "spill dir leaked after a recovered run");
    }
}

#[test]
fn budget_exhaustion_surfaces_typed_through_try_surfaces() {
    // a fault that fires on EVERY attempt exhausts the retry budget;
    // the try_* surface returns the typed error — no panic, no numbers
    let a_local = {
        let mut rng = Rng::seed(0xFA4);
        Matrix::from_fn(128, 16, |_, _| rng.gauss())
    };
    // built driver-side so stage 0 of the context is the algorithm's
    // first stage — exactly where the persistent fault is aimed
    let a = DistRowMatrix::from_matrix(&a_local, 32);
    let ctx = Context::new(4)
        .with_workers(2)
        .with_fault_plan(
            FaultPlan::default().with_persistent_target(0, 0, FaultKind::TransientCorrupt),
        )
        .with_retry_policy(RetryPolicy::new(2, 0.01));
    let err = try_algorithm2(&ctx, &NativeCompute, &a, &TallSkinnyOpts::default(), &HealthCheck::default())
        .expect_err("a persistent fault must exhaust the budget");
    match err {
        DsvdError::RetriesExhausted { stage: 0, task: 0, attempts: 2, ref last } => {
            assert!(last.contains("injected"), "last error: {last}");
        }
        other => panic!("wrong error: {other}"),
    }
    let m = ctx.take_metrics();
    assert_eq!(m.recoveries, 0);
    assert!(m.faults_injected >= 2);

    // the context survives: the fault was pinned to stage 0, so a rerun
    // (now at later stage numbers) succeeds and matches a clean run
    let recovered = try_algorithm2(
        &ctx,
        &NativeCompute,
        &a,
        &TallSkinnyOpts::default(),
        &HealthCheck::default(),
    )
    .expect("later stages are fault-free");
    let clean_ctx = Context::new(4).with_workers(2);
    let a_clean = DistRowMatrix::from_matrix(&a_local, 32);
    let clean = algorithm2(&clean_ctx, &NativeCompute, &a_clean, &TallSkinnyOpts::default());
    assert_eq!(snap(&recovered), snap(&clean), "post-failure rerun changed bits");
}

#[test]
fn poisoned_run_leaks_no_spill_temp_dirs() {
    // build the spilled grid cleanly, then kill the algorithm run with
    // an unretryable-in-budget injected panic: the typed error comes
    // back through catch_dsvd and dropping the matrix + store must
    // still remove the temp directory
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0xFA5);
    let build_ctx = Context::new(8).with_workers(2);
    let dense: DistBlockMatrix = g.generate(&build_ctx, 32, 32, BlockStorage::Dense);
    let store = SpillStore::with_budget(usize::MAX).expect("spill store");
    let dir = store.dir().to_path_buf();
    let spilled = dense.spill(&build_ctx, &store).expect("spill");
    assert!(dir.exists());

    let ctx = Context::new(8)
        .with_workers(2)
        .with_fault_plan(FaultPlan::default().with_persistent_target(0, 0, FaultKind::Panic));
    let err = dsvd::dist::catch_dsvd(|| algorithm7(&ctx, &NativeCompute, &spilled, &opts(8, 2)))
        .expect_err("stage 0 task 0 panics on every attempt");
    assert!(
        matches!(err, DsvdError::RetriesExhausted { stage: 0, task: 0, .. }),
        "wrong error: {err}"
    );
    assert!(dir.exists(), "the store must outlive the failed run");
    drop(spilled);
    drop(store);
    assert!(!dir.exists(), "poisoned run leaked its spill temp dir");
}

#[test]
fn health_guard_catches_the_silent_nonorthonormal_svd() {
    // the paper's documented failure: the stock-MLlib baseline returns
    // left singular vectors with O(1) orthogonality error and no
    // warning. The stage-boundary guard turns that into a typed error…
    let ctx = Context::new(8);
    let sigma = spectrum_geometric(64);
    let a = DctTestMatrix::new(512, 64, &sigma).generate(&ctx, &NativeCompute, 64);
    let health = HealthCheck::default();
    let ts = TallSkinnyOpts::default();
    let err = try_preexisting(&ctx, &NativeCompute, &a, &ts, &health)
        .expect_err("the stock baseline must trip the orthonormality guard");
    match err {
        DsvdError::NumericalHealth { check: "orthonormal", factor: "U", value, threshold } => {
            assert!(value > 1e-2, "drift {value} should be O(1) on this input");
            assert_eq!(threshold, 1e-6);
        }
        other => panic!("wrong error: {other}"),
    }

    // …while Algorithm 2 on the very same input passes the same guard
    let out = try_algorithm2(&ctx, &NativeCompute, &a, &ts, &health)
        .expect("the cured pipeline is orthonormal to machine precision");
    assert_eq!(out.s.len(), 64);
    assert!(ctx.metrics().health_checks_run >= 2, "guards must be counted");

    // a finite-only guard lets the baseline through (drift unchecked)
    let lax = HealthCheck::finite_only();
    assert!(try_preexisting(&ctx, &NativeCompute, &a, &ts, &lax).is_ok());
}
