//! Integration tests for the `dist` execution layer against the paper's
//! headline numbers: TSQR orthonormality at machine precision,
//! Algorithm 2's `MaxEntry(|UᵀU−I|) ≤ 1e-13`, tree-R agreement with
//! dense Householder QR, and the metrics invariants the harness tables
//! rely on. The worker-scaling check gates by default (with a robust
//! >1.3× threshold on the driver-observed clock, best of 3) and
//! self-skips on machines with fewer than 4 cores.

use dsvd::algs::{algorithm2, TallSkinnyOpts};
use dsvd::dist::{tsqr, tsqr_r, Context, DistRowMatrix};
use dsvd::gen::{spectrum_geometric, DctTestMatrix};
use dsvd::linalg::qr::thin_qr;
use dsvd::linalg::{blas, Matrix};
use dsvd::rng::Rng;
use dsvd::runtime::compute::NativeCompute;
use dsvd::verify::max_entry_gram_minus_identity;

/// The seeded 2048×64 geometric-spectrum matrix of the acceptance
/// criteria (equation (2) with spectrum (3), numerically rank-deficient).
fn seeded_2048x64(ctx: &Context) -> DistRowMatrix {
    let sigma = spectrum_geometric(64);
    DctTestMatrix::new(2048, 64, &sigma).generate(ctx, &NativeCompute, 128)
}

#[test]
fn tsqr_q_is_orthonormal_to_machine_precision() {
    let ctx = Context::new(18);
    let a = seeded_2048x64(&ctx);
    let f = tsqr(&ctx, &a);
    let orth = max_entry_gram_minus_identity(&ctx, &NativeCompute, &f.q);
    assert!(orth <= 1e-13, "explicit-Q TSQR orthonormality: {orth}");
    // and Q·R still reconstructs A
    let ql = f.q.collect(&ctx);
    let al = a.collect(&ctx);
    let rec = blas::matmul(&ql, &f.r).sub(&al).max_abs();
    assert!(rec < 1e-12, "TSQR reconstruction: {rec}");
}

#[test]
fn algorithm2_hits_the_paper_machine_precision_bound() {
    // the paper's central claim (Tables 3–5, Algorithm 2 row):
    // left singular vectors orthonormal to ~machine precision
    let ctx = Context::new(18);
    let a = seeded_2048x64(&ctx);
    let out = algorithm2(&ctx, &NativeCompute, &a, &TallSkinnyOpts::default());
    let u_orth = max_entry_gram_minus_identity(&ctx, &NativeCompute, &out.u);
    assert!(u_orth <= 1e-13, "MaxEntry(|UᵀU−I|) = {u_orth} > 1e-13");
}

#[test]
fn tsqr_r_agrees_with_dense_householder_up_to_signs() {
    // R of a full-rank matrix is unique up to row signs; normalize each
    // row by its diagonal sign and compare against a dense local QR
    let ctx = Context::new(8).with_fan_in(2);
    let mut rng = Rng::seed(9001);
    let a_local = Matrix::from_fn(1500, 24, |_, _| rng.gauss());
    let d = DistRowMatrix::from_matrix(&a_local, 100);
    let r_tree = tsqr_r(&ctx, &d);
    let r_dense = thin_qr(&a_local).r;
    assert_eq!(r_tree.shape(), r_dense.shape());
    for i in 0..r_tree.rows() {
        let st = r_tree[(i, i)].signum();
        let sd = r_dense[(i, i)].signum();
        assert!(st != 0.0 && sd != 0.0, "unexpected zero diagonal at {i}");
        for j in 0..r_tree.cols() {
            let x = st * r_tree[(i, j)];
            let y = sd * r_dense[(i, j)];
            assert!((x - y).abs() < 1e-10 * (1.0 + y.abs()), "({i},{j}): {x} vs {y}");
        }
    }
}

#[test]
fn tsqr_is_deterministic_across_worker_counts() {
    let sigma = spectrum_geometric(48);
    let run = |workers: usize| {
        let ctx = Context::new(16).with_workers(workers);
        let a = DctTestMatrix::new(1024, 48, &sigma).generate(&ctx, &NativeCompute, 64);
        tsqr_r(&ctx, &a)
    };
    let r1 = run(1);
    let r4 = run(4);
    assert_eq!(r1.data(), r4.data(), "R must be bit-identical for any worker count");
}

#[test]
fn harness_metrics_invariants() {
    // pinned to the free comms model: `cpu_time >= wall_clock` is the
    // free-model invariant (nonzero models guarantee cpu + comms >= wall)
    let ctx = Context::new(18).with_comms(dsvd::dist::FREE_COMMS);
    let a = seeded_2048x64(&ctx);
    ctx.reset_metrics();
    let _r = tsqr_r(&ctx, &a);
    let m = ctx.take_metrics();
    assert!(m.tasks >= 16, "16 leaf tasks plus merges, got {}", m.tasks);
    assert!(m.stages >= 1 + 4, "leaf stage + ⌈log2 16⌉ levels, got {}", m.stages);
    assert!(m.cpu_time > 0.0);
    assert!(m.wall_clock > 0.0);
    assert!(m.shuffle_bytes > 0, "R factors must be accounted as shuffled");
    // the tables' invariant: summed task time can never be beaten by
    // the simulated schedule of those same tasks
    assert!(m.cpu_time >= m.wall_clock, "cpu {} < wall {}", m.cpu_time, m.wall_clock);
}

/// Acceptance criterion for the parallel layer, gating by default since
/// PR 4: with 4 workers on a ≥4-core machine, `tsqr_r` on a 16384×64
/// partitioned matrix must beat 1 worker by >1.3× on the
/// driver-observed clock (`Metrics::driver_elapsed`, best of 3). The
/// PR-1 form demanded exactly ≥2× of a raw `Instant` timing and was too
/// noise-sensitive to un-ignore; 1.3× with best-of-3 sits far outside
/// scheduler jitter while still catching real scaling regressions (an
/// accidentally serialized stage scores ≈1.0×). Self-skips below 4
/// cores, where the contract is unobservable.
#[test]
fn tsqr_worker_scaling_speedup() {
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    if cores < 4 {
        eprintln!("skipping: only {cores} cores available");
        return;
    }
    let sigma = spectrum_geometric(64);
    // generate once (untimed), share the local rows across both pools
    let a_local = {
        let ctx = Context::new(16);
        DctTestMatrix::new(16384, 64, &sigma).generate(&ctx, &NativeCompute, 1024).collect(&ctx)
    };
    let timed = |workers: usize| -> f64 {
        let ctx = Context::new(64).with_workers(workers);
        let a = DistRowMatrix::from_matrix(&a_local, 1024);
        let _ = tsqr_r(&ctx, &a); // warm-up
        (0..3)
            .map(|_| {
                ctx.reset_metrics();
                let _ = tsqr_r(&ctx, &a);
                ctx.take_metrics().driver_elapsed
            })
            .fold(f64::INFINITY, f64::min)
    };
    let t1 = timed(1);
    if t1 < 0.05 {
        // the workload ran too fast for the clock to resolve a ratio
        // (release builds on fast hardware): scaling is unmeasurable
        // here, not broken
        eprintln!("skipping: 1-worker baseline only {t1:.4}s, too fast to measure");
        return;
    }
    let t4 = timed(4);
    let speedup = t1 / t4;
    println!("tsqr_r 16384x64: 1 worker {t1:.3}s, 4 workers {t4:.3}s, speedup {speedup:.2}x");
    assert!(speedup > 1.3, "expected >1.3x, got {speedup:.2}x ({t1:.3}s vs {t4:.3}s)");
}
