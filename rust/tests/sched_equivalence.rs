//! Scheduler-equivalence suite: the pipelined DAG scheduler must be a
//! pure *performance* reinterpretation of the same computation.
//!
//! * Algorithms 2/5/7/8 return factors **bit-identical** between
//!   `DSVD_SCHED=barrier` and `DSVD_SCHED=pipelined` — on every storage
//!   backend (dense / CSR / implicit / spilled) and every worker count.
//!   Numerics are schedule-independent by construction: stage results
//!   return in task order and every DAG merge folds its inputs by index
//!   exactly as the staged loops did, so nothing the scheduler decides
//!   can reach a floating-point operand.
//! * The measured counters agree too: same stage and task counts, same
//!   shuffle bytes, same priced comms seconds. Only `wall_clock` (the
//!   pipelined makespan hides transfers behind compute) and
//!   `overlap_saved` may differ — and `wall_clock` never gets worse
//!   (up to measured-compute jitter between the two runs compared).
//! * Under an injected-fault schedule a pipelined-mode context falls
//!   back to the staged loops (fault coordinates are stage/task
//!   indices), so recovery stays bit-identical to a fault-free run.

use dsvd::algs::{
    algorithm2, algorithm2_csr, algorithm5, algorithm7, algorithm8, DistSvd, LowRankOpts,
    TallSkinnyOpts, TsMethod,
};
use dsvd::dist::{
    BlockStorage, CommsModel, Context, DistBlockMatrix, DistRowMatrix, FaultKind, FaultPlan,
    Metrics, SchedMode, SpillStore,
};
use dsvd::gen::{spectrum_geometric, DctTestMatrix, SparseRandTestMatrix};
use dsvd::runtime::compute::NativeCompute;

const BACKENDS: [(&str, BlockStorage); 3] = [
    ("dense", BlockStorage::Dense),
    ("csr", BlockStorage::SparseCsr),
    ("implicit", BlockStorage::Implicit),
];

/// A transfer-dominant model so the modeled seconds dwarf real compute
/// jitter: every cross-mode wall-clock comparison here is decided by
/// the simulators, not by microsecond thread-timing noise.
const COMMS: CommsModel = CommsModel { byte_latency: 1e-4, task_overhead: 1e-3 };

fn ctx(workers: usize, sched: SchedMode) -> Context {
    Context::new(8).with_workers(workers).with_comms(COMMS).with_sched(sched)
}

fn opts(l: usize, iters: usize) -> LowRankOpts {
    let mut o = LowRankOpts::new(l, iters);
    o.rows_per_part = 32;
    o
}

type Snapshot = (Vec<f64>, Vec<f64>, Vec<Vec<f64>>);

fn snap(out: &DistSvd) -> Snapshot {
    (
        out.s.clone(),
        out.v.data().to_vec(),
        out.u.parts.iter().map(|p| p.data.data().to_vec()).collect(),
    )
}

fn snap_q(q: &DistRowMatrix) -> Vec<Vec<f64>> {
    q.parts.iter().map(|p| p.data.data().to_vec()).collect()
}

/// The cross-mode metric contract: everything measured agrees except
/// the two fields the scheduler is allowed to improve.
fn assert_metric_parity(label: &str, barrier: &Metrics, pipelined: &Metrics) {
    assert_eq!(barrier.stages, pipelined.stages, "{label}: stage counts diverged");
    assert_eq!(barrier.tasks, pipelined.tasks, "{label}: task counts diverged");
    assert_eq!(
        barrier.shuffle_bytes, pipelined.shuffle_bytes,
        "{label}: shuffle bytes diverged"
    );
    assert!(
        (barrier.comms_time - pipelined.comms_time).abs() <= 1e-9 * (1.0 + barrier.comms_time),
        "{label}: priced comms seconds diverged ({} vs {})",
        barrier.comms_time,
        pipelined.comms_time
    );
    // the min-clamp guarantees pipelined <= barrier WITHIN a run; across
    // the two measured runs compared here the modeled seconds cancel
    // exactly but the real task durations jitter at microsecond scale,
    // so allow 1 ms — far above thread-timing noise on these small
    // workloads, far below the modeled transfer seconds
    assert!(
        pipelined.wall_clock <= barrier.wall_clock + 1e-3,
        "{label}: pipelined wall {} worse than barrier {}",
        pipelined.wall_clock,
        barrier.wall_clock
    );
    assert_eq!(barrier.overlap_saved, 0.0, "{label}: barrier mode hid transfers?");
    assert!(pipelined.overlap_saved >= 0.0, "{label}: negative overlap");
}

#[test]
fn algorithm2_bit_identical_across_modes_and_workers() {
    let sigma = spectrum_geometric(32);
    let gen = DctTestMatrix::new(256, 32, &sigma);
    let ts = TallSkinnyOpts::default();
    for workers in [1usize, 2, 4] {
        let cb = ctx(workers, SchedMode::Barrier);
        let a = gen.generate(&cb, &NativeCompute, 32);
        let want = snap(&algorithm2(&cb, &NativeCompute, &a, &ts));
        let mb = cb.take_metrics();

        let cp = ctx(workers, SchedMode::Pipelined);
        assert!(cp.pipelined() && !cb.pipelined());
        let a = gen.generate(&cp, &NativeCompute, 32);
        let got = snap(&algorithm2(&cp, &NativeCompute, &a, &ts));
        let mp = cp.take_metrics();

        assert_eq!(got, want, "alg2 workers={workers}: scheduler changed bits");
        assert_metric_parity(&format!("alg2 workers={workers}"), &mb, &mp);
    }
}

#[test]
fn algorithm2_csr_bit_identical_across_modes() {
    let g = SparseRandTestMatrix::new(192, 24, 0.2, 0x5ED1);
    let ts = TallSkinnyOpts::default();
    for workers in [1usize, 2, 4] {
        let cb = ctx(workers, SchedMode::Barrier);
        let a = g.generate_csr_rows(&cb, 32);
        let want = snap(&algorithm2_csr(&cb, &NativeCompute, &a, &ts));
        let mb = cb.take_metrics();

        let cp = ctx(workers, SchedMode::Pipelined);
        let a = g.generate_csr_rows(&cp, 32);
        let got = snap(&algorithm2_csr(&cp, &NativeCompute, &a, &ts));
        let mp = cp.take_metrics();

        assert_eq!(got, want, "alg2-csr workers={workers}: scheduler changed bits");
        assert_metric_parity(&format!("alg2-csr workers={workers}"), &mb, &mp);
    }
}

#[test]
fn algorithms_5_7_8_bit_identical_on_every_backend() {
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x5ED2);
    for (name, storage) in BACKENDS {
        for workers in [1usize, 2, 4] {
            let cb = ctx(workers, SchedMode::Barrier);
            let a = g.generate(&cb, 32, 32, storage);
            let want5 =
                snap_q(&algorithm5(&cb, &NativeCompute, &a, TsMethod::Randomized, &opts(8, 2)));
            let want7 = snap(&algorithm7(&cb, &NativeCompute, &a, &opts(8, 2)));
            let want8 = snap(&algorithm8(&cb, &NativeCompute, &a, &opts(8, 2)));
            let mb = cb.take_metrics();

            let cp = ctx(workers, SchedMode::Pipelined);
            let a = g.generate(&cp, 32, 32, storage);
            let got5 =
                snap_q(&algorithm5(&cp, &NativeCompute, &a, TsMethod::Randomized, &opts(8, 2)));
            let got7 = snap(&algorithm7(&cp, &NativeCompute, &a, &opts(8, 2)));
            let got8 = snap(&algorithm8(&cp, &NativeCompute, &a, &opts(8, 2)));
            let mp = cp.take_metrics();

            assert_eq!(got5, want5, "{name}/alg5 workers={workers} changed bits");
            assert_eq!(got7, want7, "{name}/alg7 workers={workers} changed bits");
            assert_eq!(got8, want8, "{name}/alg8 workers={workers} changed bits");
            assert_metric_parity(&format!("{name} workers={workers}"), &mb, &mp);
        }
    }
}

#[test]
fn spilled_backend_bit_identical_with_prefetch_within_budget() {
    // the out-of-core tier: pipelined mode adds double-buffered
    // prefetch to every product sweep — same bits, and the prefetched
    // pages must never push the resident set past the cache budget
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x5ED3);
    let block_bytes = 8 * 32 * 32;
    for workers in [1usize, 2, 4] {
        let cb = ctx(workers, SchedMode::Barrier);
        let dense: DistBlockMatrix = g.generate(&cb, 32, 32, BlockStorage::Dense);
        let store = SpillStore::with_budget(4 * block_bytes).expect("spill store");
        let spilled = dense.spill(&cb, &store).expect("spill");
        cb.reset_metrics();
        let want = snap(&algorithm7(&cb, &NativeCompute, &spilled, &opts(8, 2)));
        let mb = cb.take_metrics();
        assert!(mb.peak_resident_bytes <= 4 * block_bytes);

        let cp = ctx(workers, SchedMode::Pipelined);
        let dense: DistBlockMatrix = g.generate(&cp, 32, 32, BlockStorage::Dense);
        let store = SpillStore::with_budget(4 * block_bytes).expect("spill store");
        let spilled = dense.spill(&cp, &store).expect("spill");
        cp.reset_metrics();
        let got = snap(&algorithm7(&cp, &NativeCompute, &spilled, &opts(8, 2)));
        let mp = cp.take_metrics();

        assert_eq!(got, want, "spilled/alg7 workers={workers} changed bits");
        assert_metric_parity(&format!("spilled workers={workers}"), &mb, &mp);
        assert!(
            mp.peak_resident_bytes <= 4 * block_bytes,
            "workers={workers}: prefetch busted the budget ({} > {})",
            mp.peak_resident_bytes,
            4 * block_bytes
        );
    }
}

#[test]
fn fault_recovery_bit_identical_under_pipelined_dispatch() {
    // a live fault plan makes the pipelined context fall back to the
    // staged loops (fault coordinates are stage/task indices), so the
    // PR 6 recovery invariant survives the new default scheduler: the
    // recovered run matches a fault-free pipelined run bit-for-bit
    let g = SparseRandTestMatrix::new(96, 64, 0.25, 0x5ED4);
    let plan = FaultPlan::seeded(0xFA01, 0.3)
        .with_straggle_delay(0.5)
        .with_target(1, 0, FaultKind::TransientIo);
    for workers in [1usize, 2, 4] {
        let clean = ctx(workers, SchedMode::Pipelined);
        let a = g.generate(&clean, 32, 32, BlockStorage::Dense);
        let want = snap(&algorithm7(&clean, &NativeCompute, &a, &opts(8, 2)));

        let faulted = ctx(workers, SchedMode::Pipelined).with_fault_plan(plan.clone());
        let a = g.generate(&faulted, 32, 32, BlockStorage::Dense);
        let got = snap(&algorithm7(&faulted, &NativeCompute, &a, &opts(8, 2)));
        let m = faulted.take_metrics();

        assert_eq!(got, want, "workers={workers}: recovered pipelined run changed bits");
        assert!(m.faults_injected >= 1, "workers={workers}: no faults injected");
        assert!(m.tasks_retried >= 1, "workers={workers}: nothing retried");
        assert!(m.recoveries >= 1, "workers={workers}: nothing recovered");
    }
}
