//! Property-style shape sweep over the `dist` layer: partitioning edge
//! cases (ragged last partition, single-partition matrices, slabs
//! narrower than the column count, column counts close to the slab
//! height, deep trees) × fan-in {2, 8} × worker counts {1, 2, 4}.
//!
//! For every combination the suite asserts the paper-level contracts:
//!
//! * explicit-Q TSQR returns Q orthonormal to `MaxEntry(|QᵀQ−I|) ≤ 1e-13`,
//!   an upper-triangular R, and `Q·R = A` to working precision;
//! * every result is **bit-identical across worker counts** (the layer's
//!   determinism guarantee: `DSVD_WORKERS` must never change a bit);
//! * the two-pass down-sweep [`tsqr`] and the lineage ablation
//!   [`tsqr_lineage`] agree (same R to the bit — identical up-sweeps —
//!   and the same Q up to floating-point association), while the
//!   two-pass variant's modeled shuffle bytes are strictly lower.

use dsvd::dist::{tsqr, tsqr_lineage, tsqr_r, Context, DistBlockMatrix, DistRowMatrix};
use dsvd::linalg::{blas, Matrix};
use dsvd::rng::Rng;
use dsvd::runtime::compute::NativeCompute;

fn randmat(seed: u64, m: usize, n: usize) -> Matrix {
    let mut rng = Rng::seed(seed);
    Matrix::from_fn(m, n, |_, _| rng.gauss())
}

/// The partitioning edge cases of the sweep: (label, m, n, rows_per_part).
const SHAPES: &[(&str, usize, usize, usize)] = &[
    ("ragged-last", 97, 8, 13),       // 97 = 7·13 + 6: short final slab
    ("single-partition", 64, 16, 100), // one slab holds everything
    ("n-close-to-slab", 120, 24, 25), // leaf QRs nearly square
    ("slabs-narrower-than-n", 33, 32, 5), // leaf Rs are 5×32, k = 5
    ("deep-tree", 256, 12, 8),        // 32 partitions: 5 levels at fan-in 2
];

fn ctx_for(fan: usize, workers: usize) -> Context {
    Context::new(16).with_fan_in(fan).with_workers(workers)
}

#[test]
fn tsqr_orthonormality_and_reconstruction_across_shapes() {
    for &(label, m, n, rpp) in SHAPES {
        let a = randmat(0xD15 ^ m as u64, m, n);
        for fan in [2usize, 8] {
            let ctx = ctx_for(fan, 2);
            let d = DistRowMatrix::from_matrix(&a, rpp);
            let f = tsqr(&ctx, &d);
            let k = f.r.rows();
            assert!(k <= m.min(n), "{label} fan={fan}: k={k}");
            for i in 0..k {
                for j in 0..i.min(f.r.cols()) {
                    assert_eq!(f.r[(i, j)], 0.0, "{label} fan={fan}: R not upper triangular");
                }
            }
            let ql = f.q.collect(&ctx);
            let orth = blas::matmul(&ql.transpose(), &ql).sub(&Matrix::eye(k)).max_abs();
            assert!(orth <= 1e-13, "{label} fan={fan}: MaxEntry(|QᵀQ−I|) = {orth}");
            let rec = blas::matmul(&ql, &f.r).sub(&a).max_abs();
            assert!(rec < 1e-12 * (1.0 + a.max_abs()), "{label} fan={fan}: recon {rec}");
        }
    }
}

#[test]
fn tsqr_bit_identical_across_worker_counts() {
    for &(label, m, n, rpp) in SHAPES {
        let a = randmat(0xB17 ^ (m * n) as u64, m, n);
        for fan in [2usize, 8] {
            let mut reference: Option<(Vec<Vec<f64>>, Vec<f64>)> = None;
            for workers in [1usize, 2, 4] {
                let ctx = ctx_for(fan, workers);
                let d = DistRowMatrix::from_matrix(&a, rpp);
                let f = tsqr(&ctx, &d);
                let q_parts: Vec<Vec<f64>> =
                    f.q.parts.iter().map(|p| p.data.data().to_vec()).collect();
                let r_data = f.r.data().to_vec();
                match &reference {
                    None => reference = Some((q_parts, r_data)),
                    Some((q_ref, r_ref)) => {
                        assert_eq!(
                            &q_parts, q_ref,
                            "{label} fan={fan} workers={workers}: Q changed bits"
                        );
                        assert_eq!(
                            &r_data, r_ref,
                            "{label} fan={fan} workers={workers}: R changed bits"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn tsqr_r_bit_identical_across_worker_counts_and_to_explicit() {
    for &(label, m, n, rpp) in SHAPES {
        let a = randmat(0xAA ^ m as u64, m, n);
        for fan in [2usize, 8] {
            let mut reference: Option<Vec<f64>> = None;
            for workers in [1usize, 2, 4] {
                let ctx = ctx_for(fan, workers);
                let d = DistRowMatrix::from_matrix(&a, rpp);
                let r = tsqr_r(&ctx, &d);
                // the explicit-Q up-sweep runs the identical R tree
                let r_explicit = tsqr(&ctx, &d).r;
                assert_eq!(
                    r.data(),
                    r_explicit.data(),
                    "{label} fan={fan}: R-only vs explicit-Q up-sweep"
                );
                match &reference {
                    None => reference = Some(r.data().to_vec()),
                    Some(r_ref) => assert_eq!(
                        r.data(),
                        &r_ref[..],
                        "{label} fan={fan} workers={workers}: R changed bits"
                    ),
                }
            }
        }
    }
}

/// Regression for the PR-2 TSQR refactor: the two-pass down-sweep must
/// return the same factorization the lineage implementation produced —
/// R to the bit (identical up-sweeps), Q to floating-point association
/// (the lineage folds its transform products left-to-right, the
/// down-sweep right-to-left) — while strictly lowering the modeled
/// shuffle volume at every partitioning.
#[test]
fn two_pass_matches_lineage_and_ships_fewer_bytes() {
    for &(label, m, n, rpp) in SHAPES {
        for fan in [2usize, 8] {
            let ctx = ctx_for(fan, 2);
            let a = randmat(0x2FA55 ^ m as u64, m, n);
            let d = DistRowMatrix::from_matrix(&a, rpp);

            ctx.reset_metrics();
            let two_pass = tsqr(&ctx, &d);
            let bytes_two_pass = ctx.take_metrics().shuffle_bytes;
            let lineage = tsqr_lineage(&ctx, &d);
            let bytes_lineage = ctx.take_metrics().shuffle_bytes;

            assert_eq!(
                two_pass.r.data(),
                lineage.r.data(),
                "{label} fan={fan}: up-sweep R must be bit-identical"
            );
            let q2 = two_pass.q.collect(&ctx);
            let q1 = lineage.q.collect(&ctx);
            let dq = q2.sub(&q1).max_abs();
            assert!(dq <= 1e-13, "{label} fan={fan}: |Q_two_pass − Q_lineage| = {dq}");
            assert!(
                bytes_two_pass < bytes_lineage,
                "{label} fan={fan}: two-pass shuffled {bytes_two_pass} B, \
                 lineage {bytes_lineage} B"
            );
        }
    }
}

#[test]
fn block_matrix_ops_bit_identical_across_worker_counts() {
    // ragged grids: 33×21 in 10×8 blocks (short last block row AND
    // column), plus a single-block grid
    let a = randmat(0xB10C, 33, 21);
    let w = randmat(0xB10D, 21, 4);
    let q_local = randmat(0xB10E, 33, 4);
    for (rpb, cpb) in [(10usize, 8usize), (64, 64), (33, 7), (5, 21)] {
        let mut reference: Option<(Vec<f64>, Vec<f64>)> = None;
        for workers in [1usize, 2, 4] {
            let ctx = Context::new(8).with_fan_in(2).with_workers(workers);
            let d = DistBlockMatrix::from_matrix(&a, rpb, cpb);
            let q = DistRowMatrix::from_matrix(&q_local, 9);
            let y = d.matmul_small(&ctx, &NativeCompute, &w).collect(&ctx);
            let z = d.rmatmul_small(&ctx, &NativeCompute, &q);
            match &reference {
                None => {
                    // correctness once per grid against the dense reference
                    assert!(
                        y.sub(&blas::matmul(&a, &w)).max_abs() < 1e-12,
                        "matmul_small grid {rpb}x{cpb}"
                    );
                    let want = blas::matmul(&a.transpose(), &q_local);
                    assert!(
                        z.sub(&want).max_abs() < 1e-11,
                        "rmatmul_small grid {rpb}x{cpb}"
                    );
                    reference = Some((y.data().to_vec(), z.data().to_vec()));
                }
                Some((y_ref, z_ref)) => {
                    assert_eq!(y.data(), &y_ref[..], "grid {rpb}x{cpb} workers={workers}");
                    assert_eq!(z.data(), &z_ref[..], "grid {rpb}x{cpb} workers={workers}");
                }
            }
        }
    }
}

#[test]
fn deep_grid_rmatmul_reduce_parallelizes_and_stays_deterministic() {
    // ROADMAP open item: on a very tall grid (24 block-rows, a single
    // block-column) the per-column fold must climb fan-in-sized chunks
    // — ⌈log₂ 24⌉ = 5 reduce levels at fan-in 2, 24 reduce tasks —
    // instead of serializing the whole column in one task, while
    // staying bit-identical across worker counts.
    let a = randmat(0xDEE9, 96, 7);
    let q_local = randmat(0xDEEA, 96, 3);
    let mut reference: Option<Vec<f64>> = None;
    for workers in [1usize, 2, 4] {
        let ctx = Context::new(8).with_fan_in(2).with_workers(workers);
        let d = DistBlockMatrix::from_matrix(&a, 4, 7);
        assert_eq!(d.num_blocks(), (24, 1));
        let q = DistRowMatrix::from_matrix(&q_local, 10);
        ctx.reset_metrics();
        let z = d.rmatmul_small(&ctx, &NativeCompute, &q);
        let m = ctx.take_metrics();
        let want = blas::matmul(&a.transpose(), &q_local);
        assert!(z.sub(&want).max_abs() < 1e-11, "workers={workers}");
        // 1 map stage + 5 chunked reduce levels (24→12→6→3→2→1)
        assert!(m.stages >= 6, "workers={workers}: stages {}", m.stages);
        // 24 map tasks + 12+6+3+2+1 = 24 reduce tasks
        assert!(m.tasks >= 48, "workers={workers}: tasks {}", m.tasks);
        match &reference {
            None => reference = Some(z.data().to_vec()),
            Some(r) => assert_eq!(z.data(), &r[..], "workers={workers}: bits changed"),
        }
    }
}

#[test]
fn comms_model_never_changes_results_only_wall_clock() {
    use dsvd::dist::CommsModel;
    let a = randmat(0xC0515, 128, 12);
    let d = DistRowMatrix::from_matrix(&a, 9);

    let free_ctx =
        Context::new(8).with_fan_in(2).with_workers(2).with_comms(dsvd::dist::FREE_COMMS);
    let free = tsqr(&free_ctx, &d);
    let free_metrics = free_ctx.take_metrics();

    let priced_ctx = Context::new(8)
        .with_fan_in(2)
        .with_workers(2)
        .with_comms(CommsModel { byte_latency: 1e-3, task_overhead: 1e-2 });
    let priced = tsqr(&priced_ctx, &d);
    let priced_metrics = priced_ctx.take_metrics();

    // identical numerics...
    assert_eq!(free.r.data(), priced.r.data());
    for (pf, pp) in free.q.parts.iter().zip(&priced.q.parts) {
        assert_eq!(pf.data.data(), pp.data.data());
    }
    // ...identical shuffle accounting...
    assert_eq!(free_metrics.shuffle_bytes, priced_metrics.shuffle_bytes);
    // ...but the priced schedule is strictly slower and records comms
    assert!(priced_metrics.comms_time > 0.0);
    assert!(priced_metrics.wall_clock > free_metrics.wall_clock);
    // honest invariant under a nonzero model
    assert!(
        priced_metrics.cpu_time + priced_metrics.comms_time
            >= priced_metrics.wall_clock - 1e-9
    );
}
