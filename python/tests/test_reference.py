"""Tests for the serial reference implementation (python/reference):
self-consistency against the paper's claims, agreement with numpy's SVD
on benign inputs, and the exact accuracy contrasts of the tables.
"""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from reference import algorithms as ref  # noqa: E402


def errors(a, u, s, v):
    recon = np.linalg.norm(a - (u * s) @ v.T, 2)
    u_orth = np.abs(u.T @ u - np.eye(u.shape[1])).max()
    v_orth = np.abs(v.T @ v - np.eye(v.shape[1])).max()
    return recon, u_orth, v_orth


@pytest.fixture(scope="module")
def ill_conditioned():
    sigma = ref.spectrum_geometric(128)
    return ref.dct_test_matrix(1024, 128, sigma)


def test_dct_test_matrix_has_requested_spectrum():
    sigma = ref.spectrum_geometric(64)
    a = ref.dct_test_matrix(256, 64, sigma)
    s = np.linalg.svd(a, compute_uv=False)
    np.testing.assert_allclose(s[:8], sigma[:8], rtol=1e-9)


def test_algorithm1_profile(ill_conditioned):
    u, s, v = ref.algorithm1(ill_conditioned)
    recon, u_orth, v_orth = errors(ill_conditioned, u, s, v)
    assert recon < 5e-11
    assert 1e-10 < u_orth < 1e-3  # eps·cond(R11): visible but bounded
    assert v_orth < 1e-12


def test_algorithm2_machine_precision(ill_conditioned):
    u, s, v = ref.algorithm2(ill_conditioned)
    recon, u_orth, v_orth = errors(ill_conditioned, u, s, v)
    assert recon < 5e-11
    assert u_orth < 1e-12  # the headline
    assert v_orth < 1e-12


def test_algorithm3_half_digits(ill_conditioned):
    u, s, v = ref.algorithm3(ill_conditioned)
    recon, u_orth, v_orth = errors(ill_conditioned, u, s, v)
    assert 1e-13 < recon < 5e-6  # Gram loses half the digits
    assert u_orth < 1e-2
    assert v_orth < 1e-12


def test_algorithm4_double(ill_conditioned):
    u, s, v = ref.algorithm4(ill_conditioned)
    recon, u_orth, v_orth = errors(ill_conditioned, u, s, v)
    assert recon < 5e-6
    assert u_orth < 1e-12
    assert v_orth < 1e-12


def test_preexisting_silent_failure(ill_conditioned):
    u, s, v = ref.preexisting(ill_conditioned)
    _, u_orth, v_orth = errors(ill_conditioned, u, s, v)
    assert u_orth > 1e-2  # O(1) without warning
    assert v_orth < 1e-12


def test_singular_values_match_numpy(ill_conditioned):
    want = np.linalg.svd(ill_conditioned, compute_uv=False)
    for alg in (ref.algorithm1, ref.algorithm2):
        _, s, _ = alg(ill_conditioned)
        np.testing.assert_allclose(s[:16], want[:16], rtol=1e-8)


def test_algorithm7_vs_8_contrast():
    sigma = ref.spectrum_lowrank(96, 12)
    a = ref.dct_test_matrix(192, 96, sigma)
    u7, s7, v7 = ref.algorithm7(a, 12, 2)
    u8, s8, v8 = ref.algorithm8(a, 12, 2)
    r7, uo7, _ = errors(a, u7, s7, v7)
    r8, uo8, _ = errors(a, u8, s8, v8)
    assert uo7 < 1e-12 and uo8 < 1e-12
    assert r7 < r8 / 10, f"alg7 {r7} must beat alg8 {r8}"


def test_srft_orthogonal():
    rng = np.random.default_rng(0)
    om = ref.Srft(32, rng)
    x = rng.standard_normal(32)
    y = om.forward(x)
    assert abs(np.linalg.norm(y) - np.linalg.norm(x)) < 1e-12
    np.testing.assert_allclose(om.inverse(y), x, atol=1e-12)


def test_devils_staircase_matches_paper_shape():
    s = ref.devils_staircase(2000)
    assert len(s) == 2000
    assert abs(s[0] - 1.0) < 1e-12
    assert s[-1] >= 0.0
    assert len(set(s.tolist())) < 500


def test_staircase_agrees_with_rust_port():
    # the Rust port (rust/src/gen.rs) small-k exact value
    s = ref.devils_staircase(2)
    assert abs(s[0] - 32.0 / 64.0 / (1 - 1.0 / 64.0)) < 1e-12
    assert s[1] == 0.0
