"""Pallas kernels vs the pure-jnp oracle — the CORE correctness signal.

Hypothesis sweeps data distributions and tile/block configurations;
deterministic tests pin the exact shapes the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

RTOL = 1e-13
ATOL = 1e-13


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, dtype=jnp.float64)


# ---------------------------------------------------------------------------
# deterministic checks at the artifact shapes
# ---------------------------------------------------------------------------


def test_matmul_artifact_shape():
    rng = np.random.default_rng(0)
    a = rand(rng, 256, 256)
    b = rand(rng, 256, 256)
    got = pk.matmul(a, b)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_matmul_narrow_artifact_shape():
    rng = np.random.default_rng(1)
    a = rand(rng, 256, 256)
    b = rand(rng, 256, 32)
    got = pk.matmul(a, b, bn=32)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_gram_artifact_shape():
    rng = np.random.default_rng(2)
    x = rand(rng, 256, 256)
    got = pk.gram(x)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    # exact symmetry of the accumulated result
    np.testing.assert_allclose(got, got.T, rtol=0, atol=1e-12)


def test_model_graphs_match_ref():
    from compile import model

    rng = np.random.default_rng(3)
    c = rand(rng, 256, 256)
    a = rand(rng, 256, 256)
    b = rand(rng, 256, 256)
    np.testing.assert_allclose(
        model.gemm_acc(c, a, b), ref.gemm_acc_ref(c, a, b), rtol=RTOL, atol=ATOL
    )
    g = rand(rng, 256, 256)
    x = rand(rng, 256, 256)
    np.testing.assert_allclose(
        model.gram_acc(g, x), ref.gram_ref(x) + g, rtol=RTOL, atol=ATOL
    )
    cn = rand(rng, 256, 32)
    bn = rand(rng, 256, 32)
    np.testing.assert_allclose(
        model.gemm_acc_narrow(cn, a, bn), ref.gemm_acc_ref(cn, a, bn), rtol=RTOL, atol=ATOL
    )


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, block sizes, data scales
# ---------------------------------------------------------------------------

block_sizes = st.sampled_from([16, 32, 64, 128])
dims = st.sampled_from([16, 32, 64, 128, 256])


@settings(max_examples=20, deadline=None)
@given(m=dims, k=dims, n=dims, bm=block_sizes, bk=block_sizes, bn=block_sizes, seed=st.integers(0, 2**31))
def test_matmul_block_sweep(m, k, n, bm, bk, bn, seed):
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    if m % bm or k % bk or n % bn:
        return  # non-dividing blocks are rejected by construction
    rng = np.random.default_rng(seed)
    a = rand(rng, m, k)
    b = rand(rng, k, n)
    got = pk.matmul(a, b, bm=bm, bk=bk, bn=bn)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=20, deadline=None)
@given(m=dims, n=dims, bm=block_sizes, bn=block_sizes, seed=st.integers(0, 2**31))
def test_gram_block_sweep(m, n, bm, bn, seed):
    bm, bn = min(bm, m), min(bn, n)
    if m % bm or n % bn:
        return
    rng = np.random.default_rng(seed)
    x = rand(rng, m, n)
    got = pk.gram(x, bm=bm, bn=bn)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(
    scale=st.sampled_from([1e-150, 1e-20, 1e-8, 1.0, 1e8, 1e20]),
    seed=st.integers(0, 2**31),
)
def test_matmul_extreme_scales(scale, seed):
    """The paper's matrices span 1 .. 1e-20 in singular values — the tile
    kernel must stay accurate across extreme magnitudes."""
    rng = np.random.default_rng(seed)
    a = rand(rng, 64, 64, scale=scale)
    b = rand(rng, 64, 64)
    got = pk.matmul(a, b, bm=32, bk=32, bn=32)
    want = ref.matmul_ref(a, b)
    # atol scaled to the product magnitude: entries that suffer catastrophic
    # cancellation legitimately differ between summation orders
    prod_scale = float(jnp.max(jnp.abs(a))) * float(jnp.max(jnp.abs(b))) * a.shape[1]
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-14 * prod_scale)


def test_matmul_rejects_non_dividing_blocks():
    with pytest.raises(ValueError):
        pk.make_matmul(100, 100, 100, bm=64, bk=64, bn=64)


def test_zero_and_identity():
    z = jnp.zeros((64, 64), jnp.float64)
    np.testing.assert_array_equal(pk.matmul(z, z, bm=32, bk=32, bn=32), z)
    eye = jnp.eye(64, dtype=jnp.float64)
    rng = np.random.default_rng(9)
    a = rand(rng, 64, 64)
    np.testing.assert_allclose(pk.matmul(eye, a, bm=32, bk=32, bn=32), a, rtol=0, atol=0)
