# Serial reference implementation of the paper's Algorithms 1-8
# (the analog of the paper's Remark-3 Python codes at
# http://tygert.com/valid.tar.gz): easy to read, numerically faithful,
# and cross-checked against the Rust implementation by pytest.
