"""Serial numpy reference of the paper's Algorithms 1–8 (Remark 3).

The paper ships a serial Python implementation alongside the Spark one
("the Python is far easier to read and run"); this module plays that
role for the Rust/sparklite implementation. Numerics mirror the
distributed code exactly:

* Algorithms 1–2 reconstitute Q implicitly as `B[:, :k] R₁₁⁻¹`
  (triangular solve) after a QR — the source of the eps·cond(R₁₁)
  orthogonality loss that double orthonormalization repairs;
* Algorithms 3–4 use explicit column normalization (Remark 6) and the
  √(working precision) cutoff;
* `preexisting` reproduces MLlib's computeSVD finish (Σ = √λ, rCond
  cutoff, no renormalization).

Used by python/tests/test_reference.py for self-consistency (every
accuracy contrast of the paper's tables) and for agreement with the
Rust port on shared closed forms (spectra, the Devil's staircase).
"""

import numpy as np

WORKING_PRECISION = 1e-11


# ---------------------------------------------------------------------------
# Remark 5: the SRFT mixing matrix Ω = D F S D̃ F S̃ on paired reals
# ---------------------------------------------------------------------------


class Srft:
    """Random orthogonal mixing operator on R^n, as chained
    permute→unitary-FFT→unit-circle-diagonal stages on paired reals."""

    def __init__(self, n, rng, chains=2):
        assert n >= 2
        self.n = n
        self.nc = n // 2  # fully paired complex slots
        self.odd = n % 2 == 1
        self.stages = []
        for _ in range(chains):
            perm = rng.permutation(self.nc)
            theta = rng.uniform(0.0, 2.0 * np.pi, self.nc)
            # odd n: mix the unpaired tail coordinate into the rest with a
            # random Givens rotation per stage (keeps Ω exactly orthogonal)
            tail = (rng.integers(0, n - 1), rng.uniform(0.0, 2.0 * np.pi)) if self.odd else None
            self.stages.append((perm, np.exp(1j * theta), tail))

    def _pack(self, x):
        return x[0 : 2 * self.nc : 2] + 1j * x[1 : 2 * self.nc : 2]

    def _unpack(self, z, x):
        x[0 : 2 * self.nc : 2] = z.real
        x[1 : 2 * self.nc : 2] = z.imag
        return x

    @staticmethod
    def _givens(x, i, j, theta):
        c, s = np.cos(theta), np.sin(theta)
        xi, xj = x[i], x[j]
        x[i] = c * xi - s * xj
        x[j] = s * xi + c * xj

    def forward(self, x):
        x = np.array(x, dtype=np.float64)
        for perm, diag, tail in reversed(self.stages):
            if tail is not None:
                self._givens(x, self.n - 1, tail[0], tail[1])
            z = self._pack(x)
            z = z[perm]
            z = np.fft.fft(z) / np.sqrt(self.nc)
            z = z * diag
            x = self._unpack(z, x)
        return x

    def inverse(self, x):
        x = np.array(x, dtype=np.float64)
        for perm, diag, tail in self.stages:
            z = self._pack(x)
            z = z * np.conj(diag)
            z = np.fft.ifft(z) * np.sqrt(self.nc)
            inv = np.empty_like(perm)
            inv[perm] = np.arange(self.nc)
            z = z[inv]
            x = self._unpack(z, x)
            if tail is not None:
                self._givens(x, self.n - 1, tail[0], tail[1] * -1.0)
        return x

    def forward_rows(self, a):
        return np.stack([self.forward(row) for row in a])

    def inverse_cols(self, v):
        return np.stack([self.inverse(col) for col in v.T]).T


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _significant_prefix(rdiag, wp):
    r00 = abs(rdiag[0])
    if r00 == 0.0:
        return 0
    k = 0
    for d in rdiag:
        if abs(d) >= r00 * wp:
            k += 1
        else:
            break
    return k


def _implicit_q(b, wp):
    """QR of b; Q reconstituted as b[:, :k] R₁₁⁻¹ (the Spark-faithful
    path). Returns (q, r_kept)."""
    r = np.linalg.qr(b, mode="r")
    k = _significant_prefix(np.diag(r), wp)
    if k == 0:
        raise ValueError("matrix numerically zero at the working precision")
    r11 = r[:k, :k]
    q = np.linalg.solve(r11.T, b[:, :k].T).T  # b[:, :k] @ inv(r11)
    return q, r[:k, :]


# ---------------------------------------------------------------------------
# Algorithms 1–4 + the stock baseline (problem {1})
# ---------------------------------------------------------------------------


def algorithm1(a, wp=WORKING_PRECISION, seed=0, chains=2):
    """Randomized SVD of a tall-skinny matrix, single orthonormalization."""
    rng = np.random.default_rng(seed)
    om = Srft(a.shape[1], rng, chains)
    mixed = om.forward_rows(a)
    q, r = _implicit_q(mixed, wp)
    ut, s, vt = np.linalg.svd(r, full_matrices=False)
    u = q @ ut
    v = om.inverse_cols(vt.T)
    return u, s, v


def algorithm2(a, wp=WORKING_PRECISION, seed=0, chains=2):
    """Algorithm 1 with double orthonormalization — machine-precision U."""
    rng = np.random.default_rng(seed)
    om = Srft(a.shape[1], rng, chains)
    mixed = om.forward_rows(a)
    q1, r1 = _implicit_q(mixed, wp)
    q2, r2 = _implicit_q(q1, wp)
    t = r2 @ r1
    ut, s, vt = np.linalg.svd(t, full_matrices=False)
    u = q2 @ ut
    v = om.inverse_cols(vt.T)
    return u, s, v


def algorithm3(a, wp=WORKING_PRECISION):
    """Gram-based SVD with Remark 6's explicit normalization."""
    b = a.T @ a
    lam, v = np.linalg.eigh(b)
    order = np.argsort(lam)[::-1]
    v = v[:, order]
    u_tilde = a @ v
    sigma = np.linalg.norm(u_tilde, axis=0)
    keep = sigma >= sigma.max() * np.sqrt(wp)
    keep &= sigma > 0
    u = u_tilde[:, keep] / sigma[keep]
    return u, sigma[keep], v[:, keep]


def algorithm4(a, wp=WORKING_PRECISION):
    """Gram-based SVD with double orthonormalization."""
    cutoff = np.sqrt(wp)
    b = a.T @ a
    lam, v_tilde = np.linalg.eigh(b)
    v_tilde = v_tilde[:, np.argsort(lam)[::-1]]
    y_tilde = a @ v_tilde
    sig_tilde = np.linalg.norm(y_tilde, axis=0)
    keep1 = (sig_tilde >= sig_tilde.max() * cutoff) & (sig_tilde > 0)
    y = y_tilde[:, keep1] / sig_tilde[keep1]
    v_tilde = v_tilde[:, keep1]
    sig_tilde = sig_tilde[keep1]

    z = y.T @ y
    lam2, w = np.linalg.eigh(z)
    w = w[:, np.argsort(lam2)[::-1]]
    q_tilde = y @ w
    t = np.linalg.norm(q_tilde, axis=0)
    keep2 = (t >= t.max() * cutoff) & (t > 0)
    q = q_tilde[:, keep2] / t[keep2]
    w = w[:, keep2]
    t = t[keep2]

    r = (t[:, None] * w.T) * sig_tilde[None, :] @ v_tilde.T
    p, s, vt = np.linalg.svd(r, full_matrices=False)
    return q @ p, s, vt.T


def preexisting(a, rcond=1e-9):
    """Stock MLlib computeSVD: Σ = √λ, no renormalization, rCond cutoff."""
    b = a.T @ a
    lam, v = np.linalg.eigh(b)
    order = np.argsort(lam)[::-1]
    lam, v = lam[order], v[:, order]
    sigma = np.sqrt(np.maximum(lam, 0.0))
    keep = sigma > rcond * sigma.max()
    sigma, v = sigma[keep], v[:, keep]
    u = a @ (v / sigma)
    return u, sigma, v


# ---------------------------------------------------------------------------
# Algorithms 5–8 (problem {2})
# ---------------------------------------------------------------------------


def _factor_q(y, method, wp, seed):
    if method == "randomized":
        u, _, _ = algorithm1(y, wp, seed)
    else:
        u, _, _ = algorithm3(y, wp)
    return u


def _factor_q_double(y, method, wp, seed):
    if method == "randomized":
        u, _, _ = algorithm2(y, wp, seed)
    else:
        u, _, _ = algorithm4(y, wp)
    return u


def algorithm5(a, l, iters, method="randomized", wp=WORKING_PRECISION, seed=0):
    """Randomized subspace iteration (HMT Algorithm 4.4)."""
    rng = np.random.default_rng(seed ^ 0xA160005)
    q_tilde = rng.standard_normal((a.shape[1], l))
    for j in range(iters):
        y = a @ q_tilde
        q = _factor_q(y, method, wp, seed + j)
        y_tilde = a.T @ q
        q_tilde = _factor_q(y_tilde, method, wp, seed + 100 + j)
    y = a @ q_tilde
    return _factor_q_double(y, method, wp, seed + 999)


def algorithm6(a, q):
    """B = QᵀA, small SVD, U = QŨ (HMT Algorithm 5.1)."""
    b = q.T @ a
    ut, s, vt = np.linalg.svd(b, full_matrices=False)
    return q @ ut, s, vt.T


def algorithm7(a, l, iters, wp=WORKING_PRECISION, seed=0):
    q = algorithm5(a, l, iters, "randomized", wp, seed)
    return algorithm6(a, q)


def algorithm8(a, l, iters, wp=WORKING_PRECISION, seed=0):
    q = algorithm5(a, l, iters, "gram", wp, seed)
    return algorithm6(a, q)


# ---------------------------------------------------------------------------
# the paper's test matrices (equations (2), (3), (5); Appendix B)
# ---------------------------------------------------------------------------


def spectrum_geometric(n):
    if n == 1:
        return np.array([1.0])
    j = np.arange(n)
    return np.exp(j / (n - 1) * np.log(1e-20))


def spectrum_lowrank(n, l):
    s = np.zeros(n)
    if l == 1:
        s[0] = 1.0
        return s
    j = np.arange(l)
    s[:l] = np.exp(j / (l - 1) * np.log(1e-20))
    return s


def devils_staircase(k):
    """Appendix B's Scala snippet, f32 rounding included."""
    out = []
    for j in range(k):
        x = int(np.round(np.float32(j) * np.float32(8.0**6) / np.float32(k)))
        octal = oct(x)[2:]
        binary = "".join("0" if c == "0" else "1" for c in octal)
        out.append(int(binary, 2) / 2.0**6 / (1 - 2.0**-6))
    return np.array(sorted(out, reverse=True))


def dct_test_matrix(m, n, sigma):
    """Equation (2): A = U Σ Vᵀ with orthonormal DCT bases."""
    i = np.arange(m)[:, None]
    j = np.arange(n)[None, :]
    u = np.sqrt(2.0 / m) * np.cos(np.pi * (2 * i + 1) * j / (2 * m))
    u[:, 0] = np.sqrt(1.0 / m)
    iv = np.arange(n)[:, None]
    jv = np.arange(n)[None, :]
    v = np.sqrt(2.0 / n) * np.cos(np.pi * (2 * iv + 1) * jv / (2 * n))
    v[:, 0] = np.sqrt(1.0 / n)
    return (u * np.asarray(sigma)) @ v.T
