"""L2: the JAX tile graphs the Rust coordinator executes through PJRT.

Each function here is a complete per-partition compute graph of the
paper's pipelines, written in JAX *calling the L1 Pallas kernels*, so a
single `jax.jit(...).lower(...)` emits one fused HLO module per
operation. `aot.py` lowers every entry of `OPERATIONS` once at build
time; the Rust tile engine (rust/src/runtime/) pads arbitrary partition
shapes onto these fixed tile shapes.

Python never runs at request time — these graphs exist only to be
lowered.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul as pk

jax.config.update("jax_enable_x64", True)

#: Tile edge shared with rust/src/runtime/engine.rs (keep in sync).
TILE = 256
#: Narrow right-hand-side width for thin products (A·V with small k).
NARROW = 32


def gemm_acc(c, a, b):
    """`C += A·B` on one (TILE, TILE) tile — the universal GEMM step.

    Used for: TSQR back-multiplication (Q·W), U = Q·Ũ, A·V projections,
    and the DCT test-matrix generator's `U_slab · (Σ Vᵀ)`.
    """
    return c + pk.matmul(a, b)


def gemm_acc_narrow(c, a, b):
    """`C += A·B` with a (TILE, NARROW) right-hand side — thin products
    (subspace iteration's A·Q̃ with l ≤ 32 columns, MLlib's A·(VΣ⁻¹))."""
    return c + pk.matmul(a, b, bn=NARROW)


def gram_acc(g, x):
    """`G += XᵀX` on one (TILE, TILE) tile — the treeAggregate leaf of
    Algorithms 3–4 and the stock MLlib routine."""
    return g + pk.gram(x)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


#: name → (python callable, example argument shapes)
OPERATIONS = {
    "gemm_acc_f64_256": (
        gemm_acc,
        (_spec(TILE, TILE), _spec(TILE, TILE), _spec(TILE, TILE)),
    ),
    "gemm_acc_f64_256x32": (
        gemm_acc_narrow,
        (_spec(TILE, NARROW), _spec(TILE, TILE), _spec(TILE, NARROW)),
    ),
    "gram_acc_f64_256": (
        gram_acc,
        (_spec(TILE, TILE), _spec(TILE, TILE)),
    ),
}
