"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must agree with these reference
implementations to near machine precision under pytest (see
python/tests/). No pallas, no tiling: just the mathematical contract.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def matmul_ref(a, b):
    """Plain `a @ b` in f64."""
    return jnp.dot(a, b, preferred_element_type=jnp.float64)


def gram_ref(x):
    """`xᵀ x` in f64."""
    return jnp.dot(x.T, x, preferred_element_type=jnp.float64)


def gemm_acc_ref(c, a, b):
    """`c + a @ b` in f64."""
    return c + jnp.dot(a, b, preferred_element_type=jnp.float64)
