"""L1 Pallas kernels: the FLOP-dominant tile primitives.

These are the compute hot-spots of the paper's pipelines — the
per-partition GEMM (`A_p · V`, TSQR back-multiplication, `U = Q·Ũ`) and
the per-partition Gram update (`A_pᵀ A_p`, the heart of Algorithms 3–4
and of Spark MLlib's stock `computeSVD`).

TPU-shaped even though this image executes them in interpret mode on the
CPU PJRT plugin:

* BlockSpec grids tile the operands into VMEM-sized blocks; the K grid
  dimension accumulates into the output block the way a TPU matmul
  accumulates MXU passes (grid iteration order makes the K axis
  innermost, so `o_ref` revisits are contiguous).
* f64 because the paper's whole point is the achievable precision
  (machine epsilon 2.2e-16); on a real TPU these kernels would drop to
  f32/bf16-with-f32-accumulate and the working precision would be set
  accordingly.

VMEM budget at the default (bm, bk, bn) = (128, 128, 128):
3 blocks × 128·128·8 B = 384 KiB resident — comfortably double-bufferable
inside a ~16 MiB VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _matmul_kernel(a_ref, b_ref, o_ref):
    """One (bm, bn) output block: accumulate a (bm, bk) @ (bk, bn) pass."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.lru_cache(maxsize=None)
def make_matmul(m, k, n, bm=128, bk=128, bn=128, dtype=jnp.float64):
    """Build a tiled Pallas matmul for fixed shapes (m, k) @ (k, n).

    Block sizes are clamped to the problem size; shapes must divide
    evenly (the AOT artifacts use power-of-two tiles, and the Rust tile
    engine pads to the artifact shape).
    """
    bm, bk, bn = min(bm, m), min(bk, k), min(bn, n)
    if m % bm or k % bk or n % bn:
        raise ValueError(f"block sizes ({bm},{bk},{bn}) must divide ({m},{k},{n})")
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )


def matmul(a, b, **block_kw):
    """`a @ b` through the Pallas tile kernel."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    return make_matmul(m, k, n, **block_kw)(a, b)


def _gram_kernel(x_ref, o_ref):
    """One (bn, bn) Gram block: accumulate X_rᵀ X_r over row panels."""
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].T, x_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.lru_cache(maxsize=None)
def make_gram(m, n, bm=128, bn=128, dtype=jnp.float64):
    """Build a tiled Pallas Gram kernel XᵀX for a fixed (m, n) X.

    The full (bm, n) row panel is kept in VMEM per grid step and both
    output tiles of the symmetric product are formed from it; the i/j
    grid walks the output blocks, the r grid accumulates row panels.
    """
    bm, bn = min(bm, m), min(bn, n)
    if m % bm or n % bn:
        raise ValueError(f"block sizes ({bm},{bn}) must divide ({m},{n})")

    def kernel(xi_ref, xj_ref, o_ref):
        r = pl.program_id(2)

        @pl.when(r == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            xi_ref[...].T, xj_ref[...], preferred_element_type=o_ref.dtype
        )

    grid = (n // bn, n // bn, m // bm)
    inner = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, r: (r, i)),
            pl.BlockSpec((bm, bn), lambda i, j, r: (r, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, r: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), dtype),
        interpret=True,
    )
    return lambda x: inner(x, x)


def gram(x, **block_kw):
    """`xᵀ @ x` through the Pallas tile kernel."""
    m, n = x.shape
    return make_gram(m, n, **block_kw)(x)
