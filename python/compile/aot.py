"""AOT lowering: JAX/Pallas (L2/L1) → HLO-text artifacts for the Rust
runtime (L3). Runs ONCE at build time (`make artifacts`); the Rust binary
is self-contained afterwards.

Interchange format is HLO **text**, not a serialized `HloModuleProto`:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import pathlib

import jax
from jax._src.lib import xla_client as xc

from .model import NARROW, OPERATIONS, TILE

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_lines = [f"tile={TILE}", f"narrow={NARROW}"]
    for name, (fn, specs) in sorted(OPERATIONS.items()):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        shapes = ";".join("x".join(map(str, s.shape)) for s in specs)
        manifest_lines.append(f"{name} inputs={shapes} sha256={digest}")
        print(f"wrote {path} ({len(text)} chars, inputs {shapes})")
    (out_dir / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
    print(f"wrote {out_dir / 'manifest.txt'}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default="../artifacts",
        help="directory for the .hlo.txt artifacts (default: ../artifacts)",
    )
    args = ap.parse_args()
    lower_all(pathlib.Path(args.out_dir))


if __name__ == "__main__":
    main()
