//! One-pass streaming SVD of a recommender-style workload — rows
//! genuinely arrive in slabs, each slab is absorbed with exactly one
//! fused traversal, and projection queries interleave with absorption
//! through the resident service.
//!
//!     cargo run --release --example streaming_lowrank
//!
//! Builds an 8192 × 4096 "user × item" preference matrix with a planted
//! rank-12 structure plus noise — but never holds it at rest for the
//! decomposition: user cohorts of 1024 rows arrive one at a time, the
//! [`SvdService`] absorbs each with ONE fused traversal (`Y += Aₛ·Ω`,
//! `W += Aₛᵀ·Ψₛ`, one small R-merge) and never reads it again. Queries
//! against the cached factors interleave with absorption: a query
//! issued after an absorption and before the next refresh comes back
//! as a typed [`ServiceError::Stale`] instead of a silently-outdated
//! answer. (The full matrix is also accumulated on the side, but ONLY
//! to verify the factors at the end — the service itself never touches
//! an absorbed row twice, as its `a_passes` ledger shows.)

use dsvd::algs::{ServiceError, StreamingOpts, SvdService};
use dsvd::config::RunConfig;
use dsvd::dist::DistRowMatrix;
use dsvd::linalg::Matrix;
use dsvd::rng::Rng;
use dsvd::runtime::NativeCompute;
use dsvd::verify::{spectral_norm, ResidualOp};
use std::time::Instant;

const USERS: usize = 8192;
const ITEMS: usize = 4096;
const RANK: usize = 12;
const SLABS: usize = 8;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.executors = 32;
    cfg.rows_per_part = 1024;
    let ctx = cfg.context();
    let be = NativeCompute;

    // planted low-rank structure: preferences = user-factors · item-factorsᵀ
    let mut rng = Rng::seed(4242);
    let uf: Vec<Vec<f64>> = (0..RANK).map(|_| (0..USERS).map(|_| rng.gauss()).collect()).collect();
    let vf: Vec<Vec<f64>> = (0..RANK).map(|_| (0..ITEMS).map(|_| rng.gauss()).collect()).collect();
    let weights: Vec<f64> = (0..RANK).map(|r| 10.0 * 0.7f64.powi(r as i32)).collect();
    let entry = |i: usize, j: usize| -> f64 {
        let mut s = 0.0;
        for r in 0..RANK {
            s += weights[r] * uf[r][i] * vf[r][j];
        }
        // deterministic per-entry noise
        let h = (i as u64).wrapping_mul(0x9E3779B97F4A7C15)
            ^ (j as u64).wrapping_mul(0xBF58476D1CE4E5B9);
        let noise = ((h >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 0.01;
        s + noise
    };

    let mut opts = StreamingOpts::new(RANK);
    opts.rows_per_part = cfg.rows_per_part;
    opts.ts = cfg.ts_opts();
    let mut svc = SvdService::new(&ctx, ITEMS, opts);

    // a fixed probe: "which latent tastes does this item vector hit"
    let probe: Vec<f64> = (0..ITEMS).map(|j| entry(17, j)).collect();

    let t0 = Instant::now();
    ctx.reset_metrics();
    let mut seen: Option<DistRowMatrix> = None; // kept ONLY for the final verification
    for s in 0..SLABS {
        let (r0, r1) = (USERS * s / SLABS, USERS * (s + 1) / SLABS);
        // the cohort arrives …
        let cohort = Matrix::from_fn(r1 - r0, ITEMS, |i, j| entry(r0 + i, j));
        let slab = DistRowMatrix::from_matrix(&cohort, cfg.rows_per_part);
        // … is absorbed once …
        svc.absorb(&ctx, &be, &slab);
        // … and any factors cached before it are now typed-stale
        match svc.project(&ctx, &probe) {
            Err(ServiceError::Stale { rows_absorbed, rows_factored }) => println!(
                "cohort {s}: query refused — factors cover {rows_factored}/{rows_absorbed} rows"
            ),
            Err(ServiceError::Empty) => {
                println!("cohort {s}: query refused — no factors yet")
            }
            Ok(_) => unreachable!("stale factors must not answer queries"),
        }
        svc.refresh(&ctx, &be);
        let coords = svc.project(&ctx, &probe).expect("fresh after refresh");
        println!("  after refresh: leading projection coordinate {:.3e}", coords[0].abs());

        seen = Some(match seen {
            Some(all) => all.vstack(&slab),
            None => slab,
        });
    }

    let m = ctx.take_metrics();
    println!(
        "absorbed {} rows in {} updates, served {} queries, a_passes={} — {:.2}s",
        m.rows_absorbed,
        m.sketch_updates,
        m.queries_served,
        m.a_passes,
        t0.elapsed().as_secs_f64()
    );

    // verification (outside the streaming path): the factors the service
    // holds must explain the whole arrived matrix
    let a = seen.expect("slabs absorbed");
    let (out, diag) = svc.factors().expect("fresh after the last refresh");
    let resid = ResidualOp { a: &a, u: &out.u, s: &out.s, v: &out.v };
    let err = spectral_norm(&ctx, &resid, 40, 1);
    let weakest = out.s.last().copied().unwrap_or(0.0);
    println!(
        "one-pass factors: rank={} ‖A−UΣVᵀ‖₂={:.3e}  σ_min={:.3e}  cross-cond={:.2e}",
        out.s.len(),
        err,
        weakest,
        diag.cross_cond
    );
    // every planted factor must be captured: the residual (noise floor)
    // must sit well below the weakest retained singular value
    assert!(err < 0.1 * weakest, "residual {err} not well below sigma_min {weakest}");
    println!("streaming_lowrank OK");
}
