//! Low-rank approximation of a wide block matrix — the paper's problem
//! {2} on a recommender-style workload.
//!
//!     cargo run --release --example streaming_lowrank
//!
//! Despite the file name, this is a **batch** demo: the whole
//! preference matrix is materialized up front and each algorithm runs
//! over it at rest — nothing streams. (The name anticipates the
//! ROADMAP item "One-pass streaming SVD and an incremental sketch
//! service", for which this is the designated seed workload; until
//! that lands, read "streaming" as the scenario class, not the
//! execution model.)
//!
//! Builds a 8192 × 4096 "user × item" preference matrix with a planted
//! rank-12 structure plus noise, stores it as a DistBlockMatrix (the
//! shape where no full row-set fits one machine), and compares
//! Algorithm 7, Algorithm 8, and the ARPACK-like baseline on the same
//! rank budget — reproducing the paper's Table 9/10 comparison on a
//! non-synthetic-spectrum input.

use dsvd::algs::{algorithm7, algorithm8, preexisting_lowrank, ArnoldiOpts, LowRankOpts};
use dsvd::config::RunConfig;
use dsvd::dist::DistBlockMatrix;
use dsvd::rng::Rng;
use dsvd::runtime::NativeCompute;
use dsvd::verify::{spectral_norm, ResidualOp};
use std::time::Instant;

const USERS: usize = 8192;
const ITEMS: usize = 4096;
const RANK: usize = 12;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.executors = 32;
    cfg.rows_per_part = 1024;
    cfg.cols_per_part = 1024;
    let ctx = cfg.context();
    let be = NativeCompute;

    // planted low-rank structure: preferences = user-factors · item-factorsᵀ
    let mut rng = Rng::seed(4242);
    let uf: Vec<Vec<f64>> = (0..RANK).map(|_| (0..USERS).map(|_| rng.gauss()).collect()).collect();
    let vf: Vec<Vec<f64>> = (0..RANK).map(|_| (0..ITEMS).map(|_| rng.gauss()).collect()).collect();
    let weights: Vec<f64> = (0..RANK).map(|r| 10.0 * 0.7f64.powi(r as i32)).collect();

    let a = DistBlockMatrix::generate(&ctx, USERS, ITEMS, cfg.rows_per_part, cfg.cols_per_part, |i, j| {
        let mut s = 0.0;
        for r in 0..RANK {
            s += weights[r] * uf[r][i] * vf[r][j];
        }
        // deterministic per-entry noise
        let h = (i as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ (j as u64).wrapping_mul(0xBF58476D1CE4E5B9);
        let noise = ((h >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 0.01;
        s + noise
    });
    let (nbr, nbc) = a.num_blocks();
    println!("preference matrix {}×{} in {}×{} blocks", USERS, ITEMS, nbr, nbc);

    let mut opts = LowRankOpts::new(RANK, 2);
    opts.rows_per_part = cfg.rows_per_part;

    for (name, run) in [
        ("Algorithm 7 (randomized)", 7usize),
        ("Algorithm 8 (Gram)", 8),
        ("pre-existing (ARPACK-like)", 0),
    ] {
        let t0 = Instant::now();
        ctx.reset_metrics();
        let out = match run {
            7 => algorithm7(&ctx, &be, &a, &opts),
            8 => algorithm8(&ctx, &be, &a, &opts),
            _ => preexisting_lowrank(&ctx, &be, &a, &ArnoldiOpts::new(RANK)),
        };
        let metrics = ctx.take_metrics();
        let resid = ResidualOp { a: &a, u: &out.u, s: &out.s, v: &out.v };
        let err = spectral_norm(&ctx, &resid, 40, 1);
        let weakest = out.s.last().copied().unwrap_or(0.0);
        println!(
            "{name:28} rank={:2}  ‖A−UΣVᵀ‖₂={:.3e}  σ_min={:.3e}  CPU={:.2}s  real={:.2}s",
            out.s.len(),
            err,
            weakest,
            metrics.cpu_time,
            t0.elapsed().as_secs_f64()
        );
        // every planted factor must be captured: the residual (noise floor)
        // must sit well below the weakest retained singular value
        assert!(
            err < 0.1 * weakest,
            "{name}: residual {err} not well below sigma_min {weakest}"
        );
    }
    println!("streaming_lowrank OK");
}
