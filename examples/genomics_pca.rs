//! PCA of a synthetic single-cell gene-expression matrix — the workload
//! class that motivated the paper (the Kluger lab works on genomics;
//! PCA of cells × genes matrices is the canonical first step of every
//! single-cell analysis pipeline).
//!
//!     cargo run --release --example genomics_pca
//!
//! We simulate 20,000 cells × 512 genes with 5 latent cell types plus
//! noise and dropout, distribute it, run PCA via Algorithm 2 (center the
//! columns, take the SVD), and check that the top principal components
//! separate the cell types — demonstrating the library on a realistic
//! analytics workload rather than a synthetic spectrum.

use dsvd::algs::{algorithm2, TallSkinnyOpts};
use dsvd::config::RunConfig;
use dsvd::dist::DistRowMatrix;
use dsvd::rng::Rng;
use dsvd::runtime::NativeCompute;
use dsvd::verify::error_report;

const CELLS: usize = 12_000;
const GENES: usize = 256;
const TYPES: usize = 5;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.executors = 32;
    cfg.rows_per_part = 1024;
    let ctx = cfg.context();
    let be = NativeCompute;

    // ---- simulate expression: cell i of type t has signature[t] + noise,
    // with ~60% dropout (zeros), mimicking scRNA-seq sparsity ------------
    let mut sig_rng = Rng::seed(77);
    let signatures: Vec<Vec<f64>> = (0..TYPES)
        .map(|_| (0..GENES).map(|_| (sig_rng.gauss() * 1.5).max(0.0)).collect())
        .collect();

    let a = DistRowMatrix::generate(&ctx, CELLS, GENES, cfg.rows_per_part, |i, row| {
        let mut rng = Rng::seed(1000 + i as u64);
        let t = i % TYPES;
        for (g, v) in row.iter_mut().enumerate() {
            let expr = signatures[t][g] + 0.3 * rng.gauss();
            *v = if rng.uniform() < 0.6 { 0.0 } else { expr.max(0.0) };
        }
    });
    println!("expression matrix: {} cells × {} genes, {} partitions", CELLS, GENES, a.num_partitions());

    // ---- PCA: center columns (distributed), then thin SVD ---------------
    let col_sums = {
        // mean via distributed column sums
        let ones = vec![1.0; CELLS];
        a.rmatvec(&ctx, &ones)
    };
    let means: Vec<f64> = col_sums.iter().map(|s| s / CELLS as f64).collect();
    let mut centered = a.clone();
    centered.map_rows(&ctx, |row| {
        for (v, m) in row.iter_mut().zip(&means) {
            *v -= m;
        }
    });

    let out = algorithm2(&ctx, &be, &centered, &TallSkinnyOpts::default());
    println!("PCA rank at working precision: {}", out.s.len());
    let total_var: f64 = out.s.iter().map(|s| s * s).sum();
    let top_var: f64 = out.s[..TYPES.min(out.s.len())].iter().map(|s| s * s).sum();
    println!("top-{} PCs explain {:.1}% of variance", TYPES, 100.0 * top_var / total_var);

    // ---- validation 1: factorization quality (the paper's claim) --------
    let e = error_report(&ctx, &be, &centered, &out.u, &out.s, &out.v);
    println!("‖A − UΣVᵀ‖₂ = {:.2E}, max|UᵀU−I| = {:.2E}", e.recon, e.u_orth);
    assert!(e.u_orth < 1e-12, "PC scores lost orthonormality");

    // ---- validation 2: the PC space separates cell types ----------------
    // project each cell onto the top PCs (scores = U·Σ) and check that
    // same-type cells are closer to their type centroid than to others.
    let k = TYPES;
    let scores = out.u.collect(&ctx); // CELLS × rank
    let mut centroids = vec![vec![0.0f64; k]; TYPES];
    let mut counts = vec![0usize; TYPES];
    for i in 0..CELLS {
        let t = i % TYPES;
        for c in 0..k {
            centroids[t][c] += scores[(i, c)] * out.s[c];
        }
        counts[t] += 1;
    }
    for (c, cnt) in centroids.iter_mut().zip(&counts) {
        for x in c.iter_mut() {
            *x /= *cnt as f64;
        }
    }
    let mut correct = 0usize;
    for i in 0..CELLS {
        let t = i % TYPES;
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for (tt, c) in centroids.iter().enumerate() {
            let d: f64 = (0..k)
                .map(|j| {
                    let x = scores[(i, j)] * out.s[j] - c[j];
                    x * x
                })
                .sum();
            if d < best_d {
                best_d = d;
                best = tt;
            }
        }
        if best == t {
            correct += 1;
        }
    }
    let acc = correct as f64 / CELLS as f64;
    println!("cell-type recovery from top-{k} PCs: {:.1}% (chance = {:.0}%)", 100.0 * acc, 100.0 / TYPES as f64);
    assert!(acc > 0.9, "PCA failed to separate cell types: {acc}");
    println!("genomics_pca OK");
}
