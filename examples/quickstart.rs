//! Quickstart: thin SVD of a distributed tall-skinny matrix in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Builds a 8192×128 ill-conditioned test matrix (the paper's equation
//! (2)/(3) class), runs Algorithm 2 (the recommended randomized method
//! with double orthonormalization), and prints the factors' accuracy.

use dsvd::algs::{algorithm2, TallSkinnyOpts};
use dsvd::config::RunConfig;
use dsvd::gen::{spectrum_geometric, DctTestMatrix};
use dsvd::runtime::NativeCompute;
use dsvd::verify::error_report;

fn main() {
    // a simulated cluster: 16 executors, 512-row partitions
    let mut cfg = RunConfig::default();
    cfg.executors = 16;
    cfg.rows_per_part = 512;
    let ctx = cfg.context();
    let be = NativeCompute;

    // synthesize A = U Σ Vᵀ with singular values decaying 1 → 1e-20
    let (m, n) = (8192, 128);
    let sigma = spectrum_geometric(n);
    let a = DctTestMatrix::new(m, n, &sigma).generate(&ctx, &be, cfg.rows_per_part);
    println!("A: {}×{} over {} partitions", a.rows(), a.cols(), a.num_partitions());

    // thin SVD, randomized + double orthonormalization (Algorithm 2)
    let out = algorithm2(&ctx, &be, &a, &TallSkinnyOpts::default());
    println!("rank at working precision: {}", out.s.len());
    println!("σ₁ = {:.3e}, σ_k = {:.3e}", out.s[0], out.s[out.s.len() - 1]);

    // verify like the paper's tables
    let e = error_report(&ctx, &be, &a, &out.u, &out.s, &out.v);
    println!("‖A − UΣVᵀ‖₂      = {:.2E}", e.recon);
    println!("max|UᵀU − I|      = {:.2E}  (orthonormal to ~machine precision)", e.u_orth);
    println!("max|VᵀV − I|      = {:.2E}", e.v_orth);

    let metrics = ctx.metrics();
    println!(
        "cluster metrics: {} stages, {} tasks, CPU {:.3}s, shuffle {} KiB",
        metrics.stages,
        metrics.tasks,
        metrics.cpu_time,
        metrics.shuffle_bytes / 1024
    );

    assert!(e.recon < 1e-10, "reconstruction degraded: {}", e.recon);
    assert!(e.u_orth < 1e-12, "U lost orthonormality: {}", e.u_orth);
    println!("quickstart OK");
}
