//! END-TO-END DRIVER — exercises every layer of the stack on one real
//! small workload and prints the paper's headline metric table.
//!
//!     make artifacts && cargo run --release --example full_pipeline
//!
//! Layers proven to compose:
//!   L1/L2  Pallas tile kernels, AOT-lowered to HLO text by aot.py
//!   PJRT   the Rust runtime loads + compiles the artifacts and serves
//!          them as the `pjrt` Compute backend
//!   L3     sparklite executors run the full Algorithm 1–4 + baseline
//!          suite and the Algorithm 7/8 low-rank suite on both backends
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end. The pjrt and
//! native backends must agree to ~1e-10 on every reported number (same
//! math, different engines), which is asserted here.

use dsvd::config::{Backend, RunConfig};
use dsvd::harness::{run_lowrank, run_tall_skinny, LrAlg, Spectrum, TableRow, TsAlg};

fn main() {
    let (m, n) = (4096, 256);
    let mut cfg = RunConfig::default();
    cfg.executors = 18;
    cfg.rows_per_part = 512;
    cfg.cols_per_part = 256;
    cfg.power_iters = 40;

    let mut per_backend: Vec<(String, Vec<TableRow>)> = Vec::new();
    for backend in [Backend::Native, Backend::Pjrt] {
        cfg.backend = backend;
        let be = match cfg.compute() {
            Ok(b) => b,
            Err(e) => {
                eprintln!("backend {backend:?} unavailable: {e}");
                eprintln!("(run `make artifacts` to build the Pallas HLO artifacts)");
                std::process::exit(1);
            }
        };
        println!("\n##### backend = {} #####", be.name());

        println!("\n--- problem {{1}}: tall-skinny SVD, m={m} n={n}, spectrum (3)");
        println!("{}", TableRow::header());
        let mut rows = Vec::new();
        for alg in TsAlg::ALL {
            let row = run_tall_skinny(&cfg, be.as_ref(), m, n, Spectrum::Geometric, alg);
            println!("{}", row.format());
            rows.push(row);
        }

        println!("\n--- problem {{2}}: rank-10 approximation, m={m} n={n}, i=2, spectrum (5)");
        println!("{}", TableRow::header());
        for alg in LrAlg::ALL {
            let row = run_lowrank(&cfg, be.as_ref(), m, n, 10, 2, Spectrum::LowRank(10), alg);
            println!("{}", row.format());
            rows.push(row);
        }
        per_backend.push((be.name().to_string(), rows));
    }

    // ---- the headline claims, asserted on both backends -------------------
    for (name, rows) in &per_backend {
        let ts: &[TableRow] = &rows[..5];
        assert!(ts[1].u_orth < 1e-12, "[{name}] Alg2 must give machine-precision U");
        assert!(ts[3].u_orth < 1e-12, "[{name}] Alg4 must give machine-precision U");
        assert!(ts[4].u_orth > 1e-2, "[{name}] stock MLlib must fail silently");
        assert!(ts[0].recon < 1e-10 && ts[1].recon < 1e-10, "[{name}] Alg1/2 recon at wp");
        assert!(ts[2].recon > 1e-9, "[{name}] Gram-based must lose half the digits");
        let lr: &[TableRow] = &rows[5..];
        assert!(lr[0].recon < lr[1].recon / 10.0, "[{name}] Alg7 recon must beat Alg8");
    }
    // cross-backend agreement (same seeds, same math)
    let (a, b) = (&per_backend[0].1, &per_backend[1].1);
    for (ra, rb) in a.iter().zip(b) {
        // same decade: exact bits differ (tiled vs blocked accumulation,
        // and the baseline's junk directions are roundoff-determined)
        let ratio = (ra.recon / rb.recon).max(rb.recon / ra.recon);
        assert!(
            ratio < 2.0,
            "backend disagreement on {}: {} vs {}",
            ra.algorithm,
            ra.recon,
            rb.recon
        );
    }
    println!("\nfull_pipeline OK — all layers compose, headline claims hold on both backends");
}
