//! Accuracy study — the paper's central claim, isolated and swept.
//!
//!     cargo run --release --example accuracy_study
//!
//! Sweeps condition number (via the decay floor of the spectrum) and
//! reports max|UᵀU−I| for single vs double orthonormalization and for
//! the stock baseline, showing WHERE each method starts losing
//! orthonormality — the "choosing carefully between single and double
//! orthonormalization" of the paper's conclusion, plus the SRFT chain
//! ablation of Remark 5.

use dsvd::algs::{algorithm1, algorithm2, preexisting, TallSkinnyOpts};
use dsvd::config::RunConfig;
use dsvd::gen::DctTestMatrix;
use dsvd::runtime::NativeCompute;
use dsvd::verify::max_entry_gram_minus_identity;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.executors = 16;
    cfg.rows_per_part = 512;
    let be = NativeCompute;
    let (m, n) = (4096, 128);

    println!("max|UᵀU−I| as conditioning degrades (m={m}, n={n}):\n");
    println!("{:>12} {:>14} {:>14} {:>14}", "σ_min", "Alg 1 (single)", "Alg 2 (double)", "pre-existing");
    for floor_exp in [-4i32, -8, -12, -16, -20] {
        let floor = 10f64.powi(floor_exp);
        let sigma: Vec<f64> =
            (0..n).map(|j| (j as f64 / (n as f64 - 1.0) * floor.ln()).exp()).collect();
        let ctx = cfg.context();
        let a = DctTestMatrix::new(m, n, &sigma).generate(&ctx, &be, cfg.rows_per_part);
        let opts = TallSkinnyOpts::default();
        let u1 = max_entry_gram_minus_identity(&ctx, &be, &algorithm1(&ctx, &be, &a, &opts).u);
        let u2 = max_entry_gram_minus_identity(&ctx, &be, &algorithm2(&ctx, &be, &a, &opts).u);
        let up = max_entry_gram_minus_identity(&ctx, &be, &preexisting(&ctx, &be, &a, &opts).u);
        println!("{:>12.0e} {:>14.2e} {:>14.2e} {:>14.2e}", floor, u1, u2, up);
    }

    println!("\nSRFT chain-length ablation (Remark 5), σ_min = 1e-20:");
    println!("{:>8} {:>14} {:>14}", "chains", "recon", "max|UᵀU−I|");
    let sigma: Vec<f64> =
        (0..n).map(|j| (j as f64 / (n as f64 - 1.0) * (1e-20f64).ln()).exp()).collect();
    for chains in [1usize, 2, 3, 4] {
        let ctx = cfg.context();
        let a = DctTestMatrix::new(m, n, &sigma).generate(&ctx, &be, cfg.rows_per_part);
        let opts = TallSkinnyOpts { srft_chains: chains, ..Default::default() };
        let out = algorithm2(&ctx, &be, &a, &opts);
        let e = dsvd::verify::error_report(&ctx, &be, &a, &out.u, &out.s, &out.v);
        println!("{:>8} {:>14.2e} {:>14.2e}", chains, e.recon, e.u_orth);
    }
    println!("\naccuracy_study OK");
}
