//! Sparse workloads through the DistOp layer: the same low-rank
//! pipeline (Algorithm 7) over one operator served by all three block
//! storage backends — dense, per-block CSR, and generator-backed
//! implicit.
//!
//!     cargo run --release --example sparse_lowrank
//!
//! The input is a permutation-scaled sparse matrix with an *exactly*
//! prescribed spectrum (one nonzero per used row/column), so the
//! recovered singular values can be checked against ground truth while
//! the CSR backend stores — and the comms model charges — only
//! nnz-proportional bytes.

use dsvd::algs::{algorithm7, LowRankOpts};
use dsvd::config::RunConfig;
use dsvd::dist::{BlockStorage, DistOp};
use dsvd::gen::SparseSpectrumTestMatrix;
use dsvd::runtime::NativeCompute;
use dsvd::verify::error_report;

fn main() {
    let mut cfg = RunConfig::default();
    cfg.executors = 16;
    cfg.rows_per_part = 512;
    cfg.cols_per_part = 512;
    let be = NativeCompute;

    // an 8192×2048 rank-12 sparse matrix with σ_j = 2^-j exactly
    let (m, n, l) = (8192, 2048, 12);
    let sigma: Vec<f64> = (0..l).map(|j| 0.5f64.powi(j as i32)).collect();
    let gen = SparseSpectrumTestMatrix::new(m, n, &sigma, cfg.seed);

    let mut opts = LowRankOpts::new(l, 2);
    opts.rows_per_part = cfg.rows_per_part;

    for (name, storage) in [
        ("dense", BlockStorage::Dense),
        ("csr", BlockStorage::SparseCsr),
        ("implicit", BlockStorage::Implicit),
    ] {
        let ctx = cfg.context();
        let a = gen.generate(&ctx, cfg.rows_per_part, cfg.cols_per_part, storage);
        // the algorithms only ever see the operator contract
        let op: &dyn DistOp = &a;
        println!(
            "\n[{name}] {}×{} operator, {} B stored (dense equivalent: {} B)",
            op.rows(),
            op.cols(),
            op.shuffle_bytes(),
            8 * m * n
        );

        ctx.reset_metrics();
        let out = algorithm7(&ctx, &be, op, &opts);
        let metrics = ctx.take_metrics();

        let worst = out
            .s
            .iter()
            .zip(&sigma)
            .map(|(got, want)| (got - want).abs() / want)
            .fold(0.0f64, f64::max);
        println!("  rank {} recovered; worst σ relative error {:.2E}", out.s.len(), worst);
        // verification also runs against the trait object (any DistOp
        // is a verify::LinOp), not the concrete storage
        let e = error_report(&ctx, &be, &op, &out.u, &out.s, &out.v);
        println!(
            "  ‖A − UΣVᵀ‖₂ = {:.2E}   max|UᵀU−I| = {:.2E}   max|VᵀV−I| = {:.2E}",
            e.recon, e.u_orth, e.v_orth
        );
        println!(
            "  {} stages, {} tasks, CPU {:.3}s, shuffle {} KiB",
            metrics.stages,
            metrics.tasks,
            metrics.cpu_time,
            metrics.shuffle_bytes / 1024
        );

        assert!(worst < 1e-9, "[{name}] singular values degraded: {worst}");
        assert!(e.u_orth < 1e-12, "[{name}] U lost orthonormality: {}", e.u_orth);
    }
    println!("\nsparse_lowrank OK");
}
