#!/usr/bin/env python3
"""Fit the simulated comms model to the paper's published wall clocks.

The scheduler prices communication with two knobs (see
rust/src/dist/README.md):

    DSVD_SHUFFLE_LATENCY   beta  -- seconds per shuffled byte
    DSVD_TASK_OVERHEAD     o     -- seconds per task launch

This script fits (beta, o) to the Algorithm 2 rows of the paper's
tall-skinny tables (Tables 3-5 at E=180 executors and the Appendix A
reruns, Tables 11-13, at E=18), arXiv:1612.08709.  Algorithm 2 is the
TSQR-dominated pipeline the comms model represents most directly: its
runtime is two reduction trees of R factors plus one mixing pass, so
its Spark overhead decomposes cleanly into per-task launch cost and
per-byte shuffle cost.

Model.  For a table row with total CPU seconds c, wall seconds w, and
E executors, the comms share is the wall time the CPU work cannot
explain:

    overhead = max(w - c / E, 0)  ~=  o * T + beta * B

with the task count T and shuffle volume B estimated from the
algorithm's structure under the paper's one-partition-per-executor
Spark layout (P = E):

    T = 2 * P + 2 * (P - 1)            leaves of both TSQR trees + merges
    B = 2 * 8 * m * n                  the mixed m x n matrix and the
                                       recovered Q, materialized to the
                                       shuffle between stages (f64)

The m-dependent volume is what matters: the published overheads grow
with m at fixed E, which only the materialized row data can explain.
The per-merge R-factor hops are E- and n-dependent only, and the
published small-m rows are far too cheap for them to carry a per-byte
price (Table 5's entire overhead is ~137 s) -- so they ride the
per-task term instead.

The two knobs are estimated in two stages rather than one joint least
squares, because the published overheads are super-linear in m (Spark
spills at the paper's largest size) and a joint linear fit across
three decades drives one knob negative:

  1. o from the E-contrast at fixed m: Tables 11-13 rerun the same
     matrices at E=18, and B does not depend on E, so the overhead
     difference between the E=180 and E=18 rows of each m isolates
     o * (T_180 - T_18) exactly.  Geometric mean across the decades.
  2. beta from the per-row volume residual (overhead - o * T) / B,
     geometric mean across the rows where that residual is positive.

Geometric means are the right average for data spanning decades; both
estimates are positive by construction.  Standard library only.

Usage:
    python3 scripts/fit_comms.py          # fit + report
    python3 scripts/fit_comms.py --json   # machine-readable result

The fitted defaults are recorded in rust/src/dist/README.md; rerun
this script if the reference tables or the structural model change.
"""

import argparse
import json
import math
import sys

N = 2000  # paper column count for Tables 3-5 / 11-13

# Algorithm 2 rows: (table, executors, m, cpu_seconds, wall_seconds),
# transcribed from the paper (same constants as tables_tall_skinny.rs).
ROWS = [
    ("T3", 180, 1_000_000, 6.84e4, 9.01e4),
    ("T4", 180, 100_000, 6.85e3, 3.39e3),
    ("T5", 180, 10_000, 9.26e2, 1.42e2),
    ("T11", 18, 1_000_000, 5.91e4, 5.44e4),
    ("T12", 18, 100_000, 6.85e3, 3.39e3),  # paper: Table 12 mirrors Table 4
    ("T13", 18, 10_000, 9.26e2, 1.42e2),  # paper: Table 13 mirrors Table 5
]


def structure(executors: int, m: int, n: int = N):
    """Task count and shuffle bytes of Algorithm 2 at P = E partitions."""
    p = executors
    tasks = 2 * p + 2 * (p - 1)
    shuffle_bytes = 2 * 8 * m * n
    return tasks, shuffle_bytes


def geomean(xs):
    if not xs:
        sys.exit("no usable rows for an estimate; check ROWS")
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def fit(rows):
    """Two-stage estimator for overhead = o * T + beta * B (see module doc)."""
    points = []
    for table, ex, m, cpu, wall in rows:
        tasks, bytes_ = structure(ex, m)
        overhead = max(wall - cpu / ex, 0.0)
        points.append((table, ex, m, tasks, bytes_, overhead))

    # stage 1: the E-contrast at fixed m isolates o (B cancels)
    by_m = {}
    for _, ex, m, tasks, _, overhead in points:
        by_m.setdefault(m, []).append((ex, tasks, overhead))
    contrasts = []
    for pair in by_m.values():
        if len(pair) != 2:
            continue
        (e1, t1, y1), (e2, t2, y2) = sorted(pair)
        if t2 != t1 and (y2 - y1) / (t2 - t1) > 0.0:
            contrasts.append((y2 - y1) / (t2 - t1))
    o = geomean(contrasts)

    # stage 2: the volume residual prices the shuffled byte
    residuals = [
        (overhead - o * tasks) / bytes_
        for _, _, _, tasks, bytes_, overhead in points
        if overhead - o * tasks > 0.0
    ]
    beta = geomean(residuals)
    return o, beta, points


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json", action="store_true", help="emit JSON only")
    args = ap.parse_args()

    o, beta, points = fit(ROWS)

    residuals = []
    for table, ex, _, tasks, bytes_, overhead in points:
        model = o * tasks + beta * bytes_
        residuals.append((table, ex, overhead, model))

    if args.json:
        print(
            json.dumps(
                {
                    "task_overhead_s": o,
                    "shuffle_latency_s_per_byte": beta,
                    "rows": [
                        {
                            "table": t,
                            "executors": e,
                            "observed_overhead_s": obs,
                            "modeled_overhead_s": mod,
                        }
                        for t, e, obs, mod in residuals
                    ],
                }
            )
        )
        return

    print("comms-model fit to the paper's Algorithm 2 wall clocks")
    print(f"  task overhead   o    = {o:.3e} s/task")
    print(f"  shuffle latency beta = {beta:.3e} s/byte")
    print()
    print(f"  {'table':>6} {'E':>4} {'observed s':>12} {'modeled s':>12}")
    for table, ex, obs, mod in residuals:
        print(f"  {table:>6} {ex:>4} {obs:>12.3e} {mod:>12.3e}")
    print()
    print("apply with:")
    print(f"  export DSVD_TASK_OVERHEAD={o:.3e}")
    print(f"  export DSVD_SHUFFLE_LATENCY={beta:.3e}")


if __name__ == "__main__":
    main()
