#!/usr/bin/env bash
# Lint + tier-1 verification plus the scaled perf records.
#
#   scripts/verify.sh            lint (cargo fmt --check + clippy -D
#                                warnings), tier-1 (build + tests), and
#                                the scaled benches ->
#                                BENCH_tall_skinny.json, BENCH_lowrank.json,
#                                BENCH_gen.json, BENCH_sparse.json,
#                                BENCH_fused.json, BENCH_ooc.json,
#                                BENCH_faults.json, BENCH_adaptive.json,
#                                BENCH_pipeline.json, BENCH_streaming.json,
#                                BENCH_kernels.json
#                                (fails if any record was not written; the
#                                fused, out-of-core, fault, adaptive,
#                                scheduler, streaming, and kernel benches
#                                also gate),
#                                then the DSVD_KERNEL / DSVD_SCHED /
#                                DSVD_PRECISION feature matrix in
#                                separate processes
#   FULL=1 scripts/verify.sh     also runs the timing-sensitive worker-
#                                scaling acceptance test (>=4 cores)
#
# Env passthrough:
#   DSVD_WORKERS          worker threads for the shared pool
#   DSVD_BENCH_SCALE      row divisor for the benches (default 64 here)
#   DSVD_SHUFFLE_LATENCY  simulated s/byte for the comms model (the
#                         fan-in sweeps default to 1e-9 when unset)
#   DSVD_TASK_OVERHEAD    simulated s/task (sweeps default to 5e-3)

set -euo pipefail
cd "$(dirname "$0")/../rust"

# lint gate BEFORE tier-1, so style and lint rot fail fast; a gate is
# skipped (loudly) only when the toolchain component itself is absent
# from this environment — a present-but-failing lint still fails the run
if cargo fmt --version >/dev/null 2>&1; then
    echo "== lint: cargo fmt --check"
    cargo fmt --check
else
    echo "!! rustfmt component not installed; skipping cargo fmt --check"
fi
if cargo clippy --version >/dev/null 2>&1; then
    echo "== lint: cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "!! clippy component not installed; skipping cargo clippy"
fi

echo "== tier-1: cargo build --release"
cargo build --release

# tier-1 runs under the free comms model: the cpu >= wall invariant
# tests document free-model behaviour, and the comms env vars are meant
# for the benches below
echo "== tier-1: cargo test -q"
env -u DSVD_SHUFFLE_LATENCY -u DSVD_TASK_OVERHEAD cargo test -q

SCALE="${DSVD_BENCH_SCALE:-64}"
POWER="${DSVD_BENCH_POWER:-20}"

echo "== scaled bench: tables_tall_skinny (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_tall_skinny.json" \
    cargo bench --bench tables_tall_skinny

echo "== scaled bench: tables_lowrank (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_lowrank.json" \
    cargo bench --bench tables_lowrank

echo "== scaled bench: tables_gen (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_JSON="BENCH_gen.json" \
    cargo bench --bench tables_gen

echo "== scaled bench: tables_sparse (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_sparse.json" \
    cargo bench --bench tables_sparse

# the fused-vs-unfused comparison is a GATE, not just a record: the
# bench panics (failing this script) unless the fused implicit-backend
# pass count is strictly lower than the unfused one, dense fused
# results are bit-identical to the two-call plan for workers 1/2/4,
# and a k-sketch batch costs one traversal
echo "== scaled bench + pass gate: tables_fused (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_fused.json" \
    cargo bench --bench tables_fused

# the out-of-core sweep is likewise a GATE: the bench panics unless the
# spilled runs are bit-identical to the resident plan at every budget,
# stay within the memory budget, and add zero A passes
echo "== scaled bench + out-of-core gates: tables_ooc (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_ooc.json" \
    cargo bench --bench tables_ooc

# the fault-injection sweep is a GATE too: the bench panics unless every
# faulted run (rates 0.1 / 0.3 of seeded panics, transient errors, and
# stragglers) recovers bit-identical to the fault-free reference and
# every nonzero rate actually injected faults; runs with an inert fault
# plan in the environment so only the bench's own seeded plans fire
echo "== scaled bench + fault-recovery gates: tables_faults (DSVD_BENCH_SCALE=${SCALE})"
env -u DSVD_FAULT_SEED -u DSVD_FAULT_RATE \
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_faults.json" \
    cargo bench --bench tables_faults

# the adaptive tolerance sweep is a GATE as well: every record carries
# three boolean gate fields (achieved error within the requested
# tolerance, the HMT posterior estimator a genuine upper bound, the
# adaptive pass count within one A pass of the matched fixed-rank run)
echo "== scaled bench + adaptive-execution gates: tables_adaptive (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_adaptive.json" \
    cargo bench --bench tables_adaptive

# the scheduler sweep is a GATE: every workload runs under both the
# barrier and the pipelined DAG scheduler; the bench panics unless the
# two are bit-identical, the pipelined wall clock never exceeds the
# barrier wall clock, the comms-heavy TSQR fan-in row pipelines at
# least 1.15x, and prefetch keeps the resident set within the spill
# budget on the out-of-core rows. Runs with DSVD_SCHED scrubbed from
# the environment so the bench's own per-row mode selection decides.
echo "== scaled bench + scheduler gates: tables_pipeline (DSVD_BENCH_SCALE=${SCALE})"
env -u DSVD_SCHED \
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_pipeline.json" \
    cargo bench --bench tables_pipeline

# the one-pass/streaming sweep is a GATE: every record carries boolean
# gate fields (the fused sketch charged exactly one A pass in batch and
# zero extra passes during slab absorption, the streamed factors match
# the batch one-pass run, and the reconstruction error sits inside the
# HMT envelope around the optimal rank-r error)
echo "== scaled bench + streaming gates: tables_streaming (DSVD_BENCH_SCALE=${SCALE})"
DSVD_BENCH_SCALE="$SCALE" \
DSVD_BENCH_POWER="$POWER" \
DSVD_BENCH_JSON="BENCH_streaming.json" \
    cargo bench --bench tables_streaming

# the kernel trajectory is a GATE: the blocked SIMD microkernels must
# clear 1.5x over the scalar reference on matmul/matmul_tn/gram (while
# agreeing to 1e-12 — the bench asserts that itself), and the f32
# storage windows of Algorithms 7/8 must halve the byte ledgers with
# the error columns intact
echo "== kernel + precision gates: micro_kernels"
DSVD_BENCH_JSON="BENCH_kernels.json" \
    cargo bench --bench micro_kernels

# every expected perf record must exist and be non-empty
for f in BENCH_tall_skinny.json BENCH_lowrank.json BENCH_gen.json BENCH_sparse.json \
         BENCH_fused.json BENCH_ooc.json BENCH_faults.json BENCH_adaptive.json \
         BENCH_pipeline.json BENCH_streaming.json BENCH_kernels.json; do
    if [ ! -s "$f" ]; then
        echo "!! missing perf record: $f" >&2
        exit 1
    fi
done
# and the fused record must carry both sides of the comparison
for mode in fused unfused; do
    if ! grep -q "\"mode\": \"$mode\"" BENCH_fused.json; then
        echo "!! BENCH_fused.json lacks the $mode rows of the comparison" >&2
        exit 1
    fi
done
# the out-of-core record must include a genuinely sub-budget run (one
# block resident) whose pass count matched the all-resident plan
if ! grep -q '"budget_blocks": "1"' BENCH_ooc.json; then
    echo "!! BENCH_ooc.json lacks the one-block-budget record" >&2
    exit 1
fi
if grep -q '"a_passes_match_resident": false' BENCH_ooc.json; then
    echo "!! an out-of-core run added A passes over the all-resident plan" >&2
    exit 1
fi
if ! grep -q '"a_passes_match_resident": true' BENCH_ooc.json; then
    echo "!! BENCH_ooc.json lacks the pass-equality gate field" >&2
    exit 1
fi
# the fault record must carry the recovery flag on every row, and no
# row may have failed to recover bit-identically
if ! grep -q '"recovered_bit_identical": true' BENCH_faults.json; then
    echo "!! BENCH_faults.json lacks the bit-identical-recovery gate field" >&2
    exit 1
fi
if grep -q '"recovered_bit_identical": false' BENCH_faults.json; then
    echo "!! a faulted run was not bit-identical to the fault-free reference" >&2
    exit 1
fi
# every adaptive sweep point must meet its requested tolerance, keep the
# posterior estimator an upper bound on the true error, and stay within
# one A pass of the matched fixed-rank run at the discovered rank
for gate in within_tolerance estimator_within_hmt passes_within_budget; do
    if ! grep -q "\"$gate\": true" BENCH_adaptive.json; then
        echo "!! BENCH_adaptive.json lacks the $gate gate field" >&2
        exit 1
    fi
    if grep -q "\"$gate\": false" BENCH_adaptive.json; then
        echo "!! an adaptive sweep point failed the $gate gate" >&2
        exit 1
    fi
done
# every scheduler-sweep row must be bit-identical across modes, never
# slower pipelined, within the spill budget, and the TSQR fan-in row
# must have cleared its 1.15x speedup bar
for gate in bit_identical pipelined_not_slower tsqr_fanin_speedup_ok peak_within_budget; do
    if ! grep -q "\"$gate\": true" BENCH_pipeline.json; then
        echo "!! BENCH_pipeline.json lacks the $gate gate field" >&2
        exit 1
    fi
    if grep -q "\"$gate\": false" BENCH_pipeline.json; then
        echo "!! a scheduler-sweep row failed the $gate gate" >&2
        exit 1
    fi
done
# every streaming record must hold the one-pass ledger (one A pass in
# batch, zero during absorption), match the batch one-pass factors, and
# land inside the HMT envelope
for gate in one_pass_ledger stream_matches_batch within_hmt_envelope; do
    if ! grep -q "\"$gate\": true" BENCH_streaming.json; then
        echo "!! BENCH_streaming.json lacks the $gate gate field" >&2
        exit 1
    fi
    if grep -q "\"$gate\": false" BENCH_streaming.json; then
        echo "!! a streaming record failed the $gate gate" >&2
        exit 1
    fi
done
# the blocked microkernels must have cleared the 1.5x bar on all three
# dense kernels, and the f32 storage runs must have halved the byte
# ledgers while keeping the error columns inside their envelopes
for gate in blocked_matmul_speedup_ok blocked_matmul_tn_speedup_ok blocked_gram_speedup_ok \
            f32_shuffle_halved f32_peak_halved f32_orth_ok f32_recon_ok; do
    if ! grep -q "\"$gate\": true" BENCH_kernels.json; then
        echo "!! BENCH_kernels.json lacks the $gate gate field" >&2
        exit 1
    fi
    if grep -q "\"$gate\": false" BENCH_kernels.json; then
        echo "!! the kernel trajectory failed the $gate gate" >&2
        exit 1
    fi
done
echo "== perf records: BENCH_tall_skinny.json BENCH_lowrank.json BENCH_gen.json BENCH_sparse.json BENCH_fused.json BENCH_ooc.json BENCH_faults.json BENCH_adaptive.json BENCH_pipeline.json BENCH_streaming.json BENCH_kernels.json"

# feature matrix: the kernel and precision knobs are cached per process,
# so each leg runs in its own test invocation. The scalar reference path
# must keep the equivalence, out-of-core, and fault suites green
# unchanged; the f32-equivalent accuracy path must hold under
# DSVD_PRECISION=f32 in the environment; and the default build must
# keep compiling with the PJRT stub only (the `pjrt` feature is a
# deliberate compile gate — its optional deps stay commented out).
echo "== feature matrix: scalar kernel reference (DSVD_KERNEL=scalar)"
env -u DSVD_SHUFFLE_LATENCY -u DSVD_TASK_OVERHEAD DSVD_KERNEL=scalar \
    cargo test -q --test op_equivalence --test out_of_core --test fault_tolerance
echo "== feature matrix: barrier scheduler (DSVD_SCHED=barrier)"
env -u DSVD_SHUFFLE_LATENCY -u DSVD_TASK_OVERHEAD DSVD_SCHED=barrier \
    cargo test -q --test op_equivalence --test out_of_core --test fault_tolerance \
    --test sched_equivalence
echo "== feature matrix: f32 storage path (DSVD_PRECISION=f32)"
env -u DSVD_SHUFFLE_LATENCY -u DSVD_TASK_OVERHEAD DSVD_PRECISION=f32 \
    cargo test -q --test lowrank_accuracy
echo "== feature matrix: default features compile against the pjrt stub"
cargo check --release --all-targets

if [ "${FULL:-0}" = "1" ]; then
    # the worker-scaling check gates in the debug tier-1 run already
    # (>1.3x, self-skipping below 4 cores); FULL reruns it in release
    # where kernel time dominates scheduling noise hardest
    echo "== worker-scaling acceptance, release build (tsqr_r, 16384x64, 1 vs 4 workers)"
    cargo test --release --test dist_parallel -- --nocapture tsqr_worker_scaling_speedup
fi

echo "verify OK"
