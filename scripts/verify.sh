#!/usr/bin/env bash
# Tier-1 verification plus the scaled perf record.
#
#   scripts/verify.sh            tier-1 (build + tests) and the scaled
#                                tall-skinny bench -> BENCH_tall_skinny.json
#   FULL=1 scripts/verify.sh     also runs the timing-sensitive worker-
#                                scaling acceptance test (>=4 cores)
#
# Env passthrough:
#   DSVD_WORKERS      worker threads for the shared pool
#   DSVD_BENCH_SCALE  row divisor for the bench (default 64 here)
#   DSVD_BENCH_JSON   output path for the JSON record

set -euo pipefail
cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release"
cargo build --release

echo "== tier-1: cargo test -q"
cargo test -q

echo "== scaled bench: tables_tall_skinny (DSVD_BENCH_SCALE=${DSVD_BENCH_SCALE:-64})"
DSVD_BENCH_SCALE="${DSVD_BENCH_SCALE:-64}" \
DSVD_BENCH_POWER="${DSVD_BENCH_POWER:-20}" \
DSVD_BENCH_JSON="${DSVD_BENCH_JSON:-BENCH_tall_skinny.json}" \
    cargo bench --bench tables_tall_skinny

echo "== perf record: ${DSVD_BENCH_JSON:-BENCH_tall_skinny.json}"

if [ "${FULL:-0}" = "1" ]; then
    echo "== worker-scaling acceptance (tsqr_r, 65536x64, 1 vs 4 workers)"
    cargo test --release --test dist_parallel -- --ignored --nocapture tsqr_worker_scaling_speedup
fi

echo "verify OK"
